"""§Perf hillclimb round 3: local MoE dispatch groups for cell A."""
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402

OUT = Path("experiments/dryrun")


def main():
    # H1d: the global argsort/scatter in MoE dispatch is what GSPMD turns
    # into TB-scale all-reduces (h1/h1c refuted EP and SP as causes).
    # GShard-style local dispatch groups (16, aligned with the data axis)
    # keep sort+scatter shard-local. Predict: all-reduce bytes drop >3x.
    run_cell("deepseek-v2-lite-16b", "train_4k", False, OUT,
             cfg_override={"moe_groups": 16}, tag="h1d_groups16")
    # and combined with the qwen-style SP win:
    run_cell("deepseek-v2-lite-16b", "train_4k", False, OUT,
             cfg_override={"moe_groups": 16},
             rules_override={"seq": "model"}, tag="h1e_groups16_sp")
    # mixtral + jamba get the same treatment (they share the dispatch path)
    run_cell("mixtral-8x7b", "train_4k", False, OUT,
             cfg_override={"moe_groups": 16}, tag="h1f_groups16")
    run_cell("jamba-v0.1-52b", "train_4k", False, OUT,
             cfg_override={"moe_groups": 16}, tag="h1g_groups16")


if __name__ == "__main__":
    main()
