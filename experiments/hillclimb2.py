"""§Perf hillclimb round 2 (after round-1 verdicts)."""
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402

OUT = Path("experiments/dryrun")


def main():
    jobs = [
        # Cell A round 2 — h1 (no-EP) was REFUTED; qwen's SP win suggests the
        # dominant all-reduce is activation traffic, not expert dispatch.
        # H1c: sequence-parallel activations with EP kept. Predict: all-reduce
        # bytes drop >2x like qwen's did.
        dict(arch="deepseek-v2-lite-16b", shape_name="train_4k", multi_pod=False,
             rules_override={"seq": "model"}, tag="h1c_sp"),

        # Cell B round 2 — h2 (pure DP) was REFUTED because batch only sharded
        # over data (16-way): per-device compute rose 8x. Fix: batch over
        # data AND model (256-way DP). Predict: compute back to ~baseline/16,
        # collective ~= grad reduce only.
        dict(arch="qwen2.5-3b", shape_name="train_4k", multi_pod=False,
             rules_override={"heads": None, "kv_heads": None, "ffn": None,
                             "vocab": None, "batch": ("pod", "data", "model")},
             cfg_override={"fsdp": True}, tag="h2c_dp256"),
        # and the SP winner combined with FSDP weights (halve weight HBM):
        dict(arch="qwen2.5-3b", shape_name="train_4k", multi_pod=False,
             rules_override={"seq": "model"}, cfg_override={"fsdp": True},
             tag="h2d_sp_fsdp"),

        # Cell C round 2 — h3 (no remat) CONFIRMED the memory-term win but
        # blew past HBM (21.4 GB/dev). H3c: selective remat (save matmul
        # outputs, recompute elementwise). Predict: memory term between full
        # remat and none; temp bytes fit 16 GB.
        dict(arch="nemotron-4-340b", shape_name="train_4k", multi_pod=False,
             cfg_override={"remat_policy": "dots"}, tag="h3c_dots"),
        # H3d: selective remat + sequence parallelism (qwen's win, applied to
        # the 340B: norms/elementwise are seq-sharded, cutting both HBM and
        # the TP all-reduce volume).
        dict(arch="nemotron-4-340b", shape_name="train_4k", multi_pod=False,
             rules_override={"seq": "model"},
             cfg_override={"remat_policy": "dots"}, tag="h3d_dots_sp"),
    ]
    for j in jobs:
        run_cell(out_dir=OUT, **j)


if __name__ == "__main__":
    main()
