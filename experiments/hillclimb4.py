"""§Perf round 4: decode-cell context parallelism (nemotron decode_32k was
collective-bound: 1.9s coll vs 1.0s mem)."""
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402

OUT = Path("experiments/dryrun")


def main():
    # H4: decode_32k is collective-bound because FSDP'd weights are
    # all-gathered for a 1-token matmul. Two candidate fixes:
    # (a) context-parallel KV (shard cache seq over model) — spreads the
    #     cache read but adds softmax partial reductions;
    # (b) keep weights fully sharded but batch over data only (replicate
    #     weight gather across steps is unavoidable in a single step fn).
    run_cell("nemotron-4-340b", "decode_32k", False, OUT,
             rules_override={"kv_seq": "model"}, tag="h4_cp")
    run_cell("nemotron-4-340b", "decode_32k", False, OUT,
             cfg_override={"fsdp": False}, tag="h4_nofsdp")
    run_cell("nemotron-4-340b", "decode_32k", False, OUT,
             rules_override={"kv_seq": "model"}, cfg_override={"fsdp": False},
             tag="h4_cp_nofsdp")


if __name__ == "__main__":
    main()
