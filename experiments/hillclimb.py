"""§Perf hillclimb driver: three chosen cells, hypothesis-tagged variants.

Cell A: deepseek-v2-lite-16b train_4k (most collective-bound: 92s coll vs
        3.4s compute on 16x16) — EP token redistribution is the suspect.
Cell B: qwen2.5-3b train_4k (small dense model on TP=16: per-layer TP
        all-reduces dwarf the useful compute).
Cell C: nemotron-4-340b train_4k (memory-dominant; remat/CE-chunk trades).

Run:  PYTHONPATH=src python experiments/hillclimb.py
"""
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)
from repro.train.train_step import TrainConfig  # noqa: E402

OUT = Path("experiments/dryrun")


def main():
    jobs = [
        # --- baselines that were recorded with stale analysis code ---------
        dict(arch="qwen2.5-3b", shape_name="train_4k", multi_pod=False),
        dict(arch="nemotron-4-340b", shape_name="train_4k", multi_pod=False),

        # --- Cell A: deepseek MoE collective ------------------------------
        # H1: expert-parallel token redistribution (experts sharded over
        # model) forces GSPMD to gather the token stream; sharding the
        # expert FFN dim instead keeps tokens local. Predict: collective
        # term drops by >2x, memory/compute roughly flat.
        dict(arch="deepseek-v2-lite-16b", shape_name="train_4k", multi_pod=False,
             rules_override={"experts": None}, tag="h1_noep"),
        # H1b: also stop sharding moe capacity tokens' d axis — combine with
        # sequence-parallel activations to cut the remaining all-reduces.
        dict(arch="deepseek-v2-lite-16b", shape_name="train_4k", multi_pod=False,
             rules_override={"experts": None, "seq": "model"}, tag="h1_noep_sp"),

        # --- Cell B: qwen dense TP=16 -------------------------------------
        # H2: a 3B dense model does not need TP on 256 chips. Pure DP+ZeRO:
        # weights/opt shard over data, batch over everything; collectives
        # become one grad reduce-scatter/all-gather of ~6GB instead of
        # per-layer activation all-reduces. Predict: collective term -5x.
        dict(arch="qwen2.5-3b", shape_name="train_4k", multi_pod=False,
             rules_override={"heads": None, "kv_heads": None, "ffn": None,
                             "vocab": None},
             cfg_override={"fsdp": True}, tag="h2_dponly"),
        # H2b: keep TP but add Megatron sequence parallelism for the
        # norm/elementwise activations. Predict: small collective win.
        dict(arch="qwen2.5-3b", shape_name="train_4k", multi_pod=False,
             rules_override={"seq": "model"}, tag="h2_sp"),

        # --- Cell C: nemotron memory --------------------------------------
        # H3: remat doubles forward HBM traffic; with 2.8GB/dev there is
        # headroom to keep activations. Predict: memory term drops ~25%,
        # temp bytes rise.
        dict(arch="nemotron-4-340b", shape_name="train_4k", multi_pod=False,
             cfg_override={"remat": False}, tag="h3_noremat"),
        # H3b: bigger CE chunks halve the number of head matmul sweeps.
        dict(arch="nemotron-4-340b", shape_name="train_4k", multi_pod=False,
             tcfg=TrainConfig(ce_chunk=2048), tag="h3_ce2048"),
    ]
    for j in jobs:
        run_cell(out_dir=OUT, **j)


if __name__ == "__main__":
    main()
