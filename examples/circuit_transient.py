"""End-to-end driver: transient simulation of a nonlinear power grid.

Backward-Euler + Newton-Raphson; the GLU plan is built once and ~hundreds
of refactorizations run on the fixed pattern — the paper's target workload.

  PYTHONPATH=src python examples/circuit_transient.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro.circuit import rc_grid_circuit, transient


def main():
    ckt = rc_grid_circuit(10, 10, with_diodes=True, seed=0)
    print(f"grid 10x10: {ckt.n} nodes, {len(ckt.resistors)} R, "
          f"{len(ckt.capacitors)} C, {len(ckt.diodes)} diodes, "
          f"{len(ckt.isources)} switching loads")
    res = transient(ckt, t_end=0.10, dt=0.002)
    print(f"steps={len(res.times)}  newton_iters={res.newton_iters.sum()}  "
          f"factorizations={res.n_factorizations}")
    print(f"symbolic setup {res.setup_seconds:.2f}s (once)  "
          f"numeric loop {res.solve_seconds:.2f}s "
          f"({res.solve_seconds / res.n_factorizations * 1e3:.1f} ms/refactorize+solve)")
    print(f"max Newton residual {res.max_residual:.2e}")
    vmin, vmax = res.voltages.min(), res.voltages.max()
    print(f"voltage envelope [{vmin:.3f}, {vmax:.3f}] V")
    assert np.isfinite(res.voltages).all()


if __name__ == "__main__":
    main()
