"""Serve a small LM with batched prefill+decode and the dependency-aware
scheduler (levelizer reuse from the paper's core).

  PYTHONPATH=src python examples/serve_lm.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Request, ServeEngine


def main():
    import dataclasses

    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params)
    rng = np.random.default_rng(0)

    # plain batched generation
    prompts = rng.integers(0, cfg.vocab_size, size=(4, 24)).astype(np.int32)
    out = engine.generate_batch(prompts, max_new=12)
    print("batched generation:", out.shape)

    # dependency-aware scheduling: request 2 extends request 0's output
    reqs = [
        Request(rid=0, tokens=prompts[0], max_new=8),
        Request(rid=1, tokens=prompts[1], max_new=8),
        Request(rid=2, tokens=prompts[2][:8], max_new=8, parent=0),
        Request(rid=3, tokens=prompts[3][:8], max_new=8, parent=1),
    ]
    results = engine.run(reqs, batch_size=2)
    for rid in sorted(results):
        print(f"request {rid}: {results[rid][:8].tolist()}")


if __name__ == "__main__":
    main()
