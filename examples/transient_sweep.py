"""Batched parameter sweep: N perturbed copies of a circuit, one plan.

Monte-Carlo / process-corner analysis: every copy shares the sparsity
pattern, so the GLU symbolic plan is built once and each lockstep Newton
iterate factorizes ALL copies with a single batched device dispatch per
level-group (``GLU.refactorize_solve``).

  PYTHONPATH=src python examples/transient_sweep.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro.circuit import rc_grid_circuit, transient_sweep


def main():
    ckt = rc_grid_circuit(8, 8, with_diodes=True, seed=0)
    scales = np.linspace(0.8, 1.2, 9)   # ±20% conductance corners
    print(f"grid 8x8: {ckt.n} nodes, sweeping {len(scales)} corners "
          f"{scales.round(2).tolist()}")
    res = transient_sweep(ckt, t_end=0.05, dt=0.002, scales=scales)
    print(f"steps={len(res.times)}  lockstep newton_iters={res.newton_iters.sum()}  "
          f"batched factorizations={res.n_batched_factorizations} "
          f"(x{len(scales)} matrices each)")
    print(f"symbolic setup {res.setup_seconds:.2f}s (once)  "
          f"numeric loop {res.solve_seconds:.2f}s")
    print(f"max Newton residual {res.max_residual:.2e}")
    v_final = res.voltages[:, -1, :]
    spread = v_final.max(axis=0) - v_final.min(axis=0)
    print(f"corner-to-corner final-voltage spread: "
          f"max {spread.max():.4f} V, mean {spread.mean():.4f} V")
    assert np.isfinite(res.voltages).all()


if __name__ == "__main__":
    main()
