"""AC small-signal frequency sweep: one complex plan, all points batched.

The sweep factorizes A(w) = G + jwC at every frequency on ONE symbolic
plan: the DC operating point is found with the real-valued Newton loop,
then a single batched complex128 factorize+solve covers all F points in
lockstep (``GLU.refactorize_solve`` under the hood).

  PYTHONPATH=src python examples/ac_sweep.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro.circuit import rc_grid_circuit, ac_sweep


def main():
    ckt = rc_grid_circuit(8, 8, with_diodes=True, seed=0)
    ckt.add_ac_current_source(1, 0, 1.0)   # 1A small-signal probe at node 1
    freqs = np.logspace(0, 5, 21)
    print(f"grid 8x8: {ckt.n} nodes, sweeping {len(freqs)} frequency points "
          f"[{freqs[0]:.0f} Hz .. {freqs[-1]:.0f} Hz]")
    res = ac_sweep(ckt, freqs)
    print(f"operating point found in {res.op_newton_iters} Newton iters; "
          f"batched complex factorizations: {res.n_batched_factorizations}")
    print(f"setup {res.setup_seconds:.2f}s (op point + one complex plan)  "
          f"sweep solve {res.solve_seconds:.3f}s "
          f"({res.solve_seconds / len(freqs) * 1e3:.2f} ms/point)")
    print(f"worst componentwise backward error {res.max_backward_error:.2e}")
    mag = np.abs(res.voltages[:, 0])
    print("probe-node |V(f)|:")
    for f, m in zip(freqs[::4], mag[::4]):
        print(f"  {f:>9.1f} Hz  {m:.4e} V")
    assert res.max_backward_error < 1e-10
    assert (np.diff(mag) <= 1e-12).all(), "RC grid must be low-pass at the probe"


if __name__ == "__main__":
    main()
