"""Train a small LM end-to-end with the framework's production loop:
config -> sharding rules -> AdamW -> checkpoints -> resumable pipeline.

Defaults train a ~14M-param qwen-family model for 200 steps on CPU
(a few minutes); scale --d-model/--layers/--steps up on real hardware.

  PYTHONPATH=src python examples/train_lm.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import sys

from repro.launch.train import main as train_main


def main():
    argv = [
        "--arch", "qwen2.5-3b", "--reduced",
        "--layers", "4", "--d-model", "256", "--d-ff", "1024", "--vocab", "4096",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_train_lm",
        "--log-every", "20", "--metrics-out", "experiments/train_lm_metrics.json",
    ] + sys.argv[1:]
    history = train_main(argv)
    if history:
        first, last = history[0], history[-1]
        print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} over "
              f"{last['step'] - first['step']} steps")
        assert last["loss"] < first["loss"], "training must reduce the loss"


if __name__ == "__main__":
    main()
