"""Quickstart: factorize a circuit matrix with GLU3.0 and solve Ax = b.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

import jax.numpy as jnp

from repro.core import GLU
from repro.sparse import circuit_jacobian


def main():
    # a 2000-node circuit-style sparse matrix (structurally symmetric-ish,
    # diagonally dominant — what MNA assembly produces)
    A = circuit_jacobian(2000, avg_degree=4.0, seed=0)
    b = np.random.default_rng(0).normal(size=A.n)

    # plan once: MC64 -> fill-reducing ordering -> symbolic fill-in ->
    # relaxed dependency detection (paper Alg. 4) -> levelization -> plan
    solver = GLU(A, dtype=jnp.float64)
    print(f"n={A.n}  nnz(A)={A.nnz}  nnz(L+U)={solver.nnz_filled}  "
          f"levels={solver.num_levels}")

    # numeric factorization on device (level-parallel, scan-fused)
    solver.factorize()
    x = solver.solve(b)
    print(f"residual ||Ax-b||_inf / ||b||_inf = {solver.residual(b, x):.2e}")

    # the SPICE pattern: REfactorize new values on the same pattern — no
    # symbolic work, this is the loop GLU3.0 accelerates
    for it in range(3):
        new_vals = np.asarray(A.data) * (1.0 + 0.1 * it)
        solver.factorize(new_vals)
        x = solver.solve(b)
        print(f"refactorization {it}: residual scale-invariant check "
              f"{np.abs(A.to_scipy() @ (x * (1.0 + 0.1 * it)) - b).max():.2e}")


if __name__ == "__main__":
    main()
