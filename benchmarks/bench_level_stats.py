"""Paper Fig. 10: per-level parallelism profile.

Emits (level, n_columns, max_subcolumns, total_updates) — the inverse
correlation between level size and subcolumn count is the empirical basis
for the three kernel modes.
"""
from __future__ import annotations

import numpy as np

from .common import bench_matrices, row


def main():
    from repro.core import level_stats, levelize_relaxed, symbolic_fillin

    out = []
    for name, A in bench_matrices():
        As = symbolic_fillin(A, "auto")
        lv = levelize_relaxed(As)
        st = level_stats(As, lv)
        # correlation between log(level size) and log(max subcolumns)
        sizes = st[:, 0].astype(float)
        subs = np.maximum(st[:, 1].astype(float), 1.0)
        corr = np.corrcoef(np.log(sizes), np.log(subs))[0, 1] if len(st) > 3 else 0.0
        head = ";".join(f"{l}:{s}:{m}" for l, (s, m, _u) in list(enumerate(st))[:8])
        print(f"# fig10 {name}: levels={lv.num_levels} corr(log_size,log_subs)="
              f"{corr:.2f} head={head}", flush=True)
        row(f"level_stats_{name}", float(lv.num_levels), f"corr={corr:.2f}")
        out.append({"matrix": name, "stats": st.tolist(), "corr": corr})
        np.savetxt(f"experiments/fig10_{name}.csv", st, fmt="%d",
                   header="n_columns,max_subcolumns,total_updates", delimiter=",")
    return out


if __name__ == "__main__":
    import os

    os.makedirs("experiments", exist_ok=True)
    main()
