"""AC sweep throughput: batched complex factorize+solve vs a per-frequency
single-matrix loop.

The AC small-signal workload factorizes A(w) = G + jwC at every frequency
point of a sweep on ONE symbolic plan.  The per-frequency loop pays the
full per-level dispatch overhead F times; the batched path folds all F
points into each level-group dispatch — the speedup is the paper's
dispatch-amortization argument replayed on the complex field.
"""
from __future__ import annotations

import numpy as np

from .common import row, timeit

FREQ_COUNTS = [4, 16]


def main():
    from repro.circuit import rc_grid_circuit
    from repro.core import GLU
    from repro.sparse.csc import CSC

    import jax.numpy as jnp

    ckt = rc_grid_circuit(12, 12, with_diodes=True, seed=0)
    ckt.add_ac_current_source(1, 0, 1.0)
    pat = ckt.pattern()
    v_op = np.zeros(ckt.n)
    fmax = max(FREQ_COUNTS)
    freqs_all = np.logspace(0, 6, fmax)
    vals_all, rhs_all = ckt.assemble_ac(v_op, freqs_all)

    glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals_all[0]),
              dtype=jnp.complex128)
    print(f"# ac_sweep_throughput: n={ckt.n} nnz={pat.nnz} "
          f"nnz_filled={glu.nnz_filled} levels={glu.num_levels}")
    print("# F,us_per_freq_loop,us_per_freq_batched,speedup")
    results = []
    for F in FREQ_COUNTS:
        vals, rhs = vals_all[:F], rhs_all[:F]

        def per_freq_loop():
            out = np.empty((F, ckt.n), dtype=np.complex128)
            for k in range(F):
                glu.factorize(vals[k])
                out[k] = glu.solve(rhs[k])
            return out

        t_loop, x_loop = timeit(per_freq_loop)
        t_batch, x_batch = timeit(lambda: glu.refactorize_solve(vals, rhs))
        assert np.abs(x_loop - x_batch).max() < 1e-9
        speedup = t_loop / t_batch
        print(f"{F},{t_loop / F * 1e6:.1f},{t_batch / F * 1e6:.1f},"
              f"{speedup:.2f}", flush=True)
        row(f"ac_batched_f{F}", t_batch / F * 1e6,
            f"speedup_vs_loop={speedup:.2f}x")
        results.append({"freqs": F, "per_freq_batched_s": t_batch / F,
                        "speedup_vs_loop": speedup})
    print(f"# batched complex sweep at F={FREQ_COUNTS[-1]}: "
          f"{results[-1]['speedup_vs_loop']:.2f}x the per-frequency loop")
    return results


if __name__ == "__main__":
    main()
