"""AC sweep throughput: batched complex factorize+solve vs a per-frequency
single-matrix loop, plus the planar-vs-native complex storage comparison.

The AC small-signal workload factorizes A(w) = G + jwC at every frequency
point of a sweep on ONE symbolic plan.  The per-frequency loop pays the
full per-level dispatch overhead F times; the batched path folds all F
points into each level-group dispatch — the speedup is the paper's
dispatch-amortization argument replayed on the complex field.

The layout rows compare the two complex value storages end to end
(``ac_planar_f*`` vs the native ``ac_batched_f*`` baseline), each checked
against a per-frequency scipy componentwise-backward-error oracle.  Planar
re/im-plane storage is what keeps the Pallas SEGMENTED/PANEL/dense-tail
kernels available for complex dtypes (they take no complex operands); those
kernels only COMPILE on real TPU backends — interpret mode is a correctness
emulation, not a perf path — so on CPU the planar rows measure the planar
arithmetic's flat-XLA lowering under the same single-dispatch schedule.
"""
from __future__ import annotations

import numpy as np

from .common import row, timeit

FREQ_COUNTS = [4, 16]
BERR_TOL = 1e-10


def _scipy_berr(pat, n, vals, rhs, x):
    """Worst per-frequency componentwise backward error, scipy-side:
    max_i |b - A x|_i / (|A| |x| + |b|)_i over all frequency rows."""
    import scipy.sparse as sp

    worst = 0.0
    for k in range(vals.shape[0]):
        A = sp.csc_matrix((vals[k], pat.indices, pat.indptr), shape=(n, n))
        r = np.abs(rhs[k] - A @ x[k])
        denom = np.abs(A) @ np.abs(x[k]) + np.abs(rhs[k])
        ok = denom > 0
        berr = float((r[ok] / denom[ok]).max()) if ok.any() else 0.0
        if np.any(r[~ok] > 0):
            berr = np.inf
        worst = max(worst, berr)
    return worst


def main():
    from repro.circuit import rc_grid_circuit
    from repro.core import GLU
    from repro.sparse.csc import CSC

    import jax.numpy as jnp

    ckt = rc_grid_circuit(12, 12, with_diodes=True, seed=0)
    ckt.add_ac_current_source(1, 0, 1.0)
    pat = ckt.pattern()
    v_op = np.zeros(ckt.n)
    fmax = max(FREQ_COUNTS)
    freqs_all = np.logspace(0, 6, fmax)
    vals_all, rhs_all = ckt.assemble_ac(v_op, freqs_all)

    glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals_all[0]),
              dtype=jnp.complex128)
    print(f"# ac_sweep_throughput: n={ckt.n} nnz={pat.nnz} "
          f"nnz_filled={glu.nnz_filled} levels={glu.num_levels}")
    print("# F,us_per_freq_loop,us_per_freq_batched,speedup")
    results = []
    for F in FREQ_COUNTS:
        vals, rhs = vals_all[:F], rhs_all[:F]

        def per_freq_loop():
            out = np.empty((F, ckt.n), dtype=np.complex128)
            for k in range(F):
                glu.factorize(vals[k])
                out[k] = glu.solve(rhs[k])
            return out

        t_loop, x_loop = timeit(per_freq_loop)
        t_batch, x_batch = timeit(lambda: glu.refactorize_solve(vals, rhs))
        assert np.abs(x_loop - x_batch).max() < 1e-9
        speedup = t_loop / t_batch
        print(f"{F},{t_loop / F * 1e6:.1f},{t_batch / F * 1e6:.1f},"
              f"{speedup:.2f}", flush=True)
        row(f"ac_batched_f{F}", t_batch / F * 1e6,
            f"speedup_vs_loop={speedup:.2f}x")
        results.append({"freqs": F, "per_freq_batched_s": t_batch / F,
                        "speedup_vs_loop": speedup})
    print(f"# batched complex sweep at F={FREQ_COUNTS[-1]}: "
          f"{results[-1]['speedup_vs_loop']:.2f}x the per-frequency loop")

    # -- planar vs native complex storage, scipy-oracle checked --------------
    print("# layout comparison: F,us_per_freq_native,us_per_freq_planar,"
          "berr_native,berr_planar")
    for F in FREQ_COUNTS:
        vals, rhs = vals_all[:F], rhs_all[:F]
        per = {}
        for layout in ("native", "planar"):
            g = GLU(CSC(pat.n, pat.indptr, pat.indices, vals[0]),
                    dtype=jnp.complex128, layout=layout)
            t, x = timeit(lambda g=g: g.refactorize_solve(vals, rhs))
            info = g.solve_info
            assert info["n_dispatches"] == 1, info["n_dispatches"]
            assert info["layout"] == layout, info["layout"]
            berr = _scipy_berr(pat, ckt.n, vals, rhs, np.asarray(x))
            assert berr <= BERR_TOL, (layout, berr)
            per[layout] = (t / F, berr)
        tn, bn = per["native"]
        tp, bp = per["planar"]
        print(f"{F},{tn * 1e6:.1f},{tp * 1e6:.1f},{bn:.2e},{bp:.2e}",
              flush=True)
        row(f"ac_planar_f{F}", tp * 1e6,
            f"vs_native={tn / tp:.2f}x berr={bp:.1e} dispatches=1")
        results.append({"freqs": F, "layout": "planar",
                        "per_freq_s": tp, "berr": bp,
                        "native_per_freq_s": tn, "native_berr": bn})
    return results


if __name__ == "__main__":
    main()
