"""Sparse-RHS triangular solves: reach-pruned vs full level schedule.

Circuit right-hand sides are mostly zeros (an AC excitation is often 1-2
entries), and the solution of ``L y = b`` is supported exactly on the reach
of ``nonzeros(b)`` (Gilbert-Peierls).  Pruning the level-group schedule to
that reach drops whole levels — and with them their per-level dispatch
cost, which dominates the paper's solve phase on high-level-count matrices.

Measured here on a multi-power-domain chip matrix (>= 50k nnz in the
factors): for an irreducible matrix the solution of ``A x = b`` is dense
even for 1-hot ``b``, so pruning only helps the forward sweep — the win
lives on matrices with decoupled subcircuits (isolated supply domains,
replicated macros), where a localized excitation reaches one block of the
factors.  We time a 1-hot RHS (the AC / adjoint seed shape), a density
sweep showing how the win decays as the reach saturates, and the many-RHS
``solve_multi`` path (K seed vectors against one factorization) vs K
sequential solves.  Pruned
schedules are cached per pattern, so scheduling cost is paid once per
excitation pattern — the sweep contract — and excluded from the steady
state here (it is reported separately).
"""
from __future__ import annotations

import time

import numpy as np

from .common import row, timeit

DENSITIES = [0.001, 0.01, 0.1]
MULTI_K = 16


def main():
    from repro.core import GLU
    from repro.sparse import multi_domain_circuit

    A = multi_domain_circuit(seed=0)     # one 1600-node + twelve 400-node domains
    glu = GLU(A).factorize()
    assert glu.nnz_filled >= 50_000      # the factors the trisolve runs on
    solver = glu._solver
    n = A.n
    rng = np.random.default_rng(0)
    print(f"# sparse_rhs: n={n} nnz={A.nnz} nnz_filled={glu.nnz_filled} "
          f"levels={glu.num_levels}")

    b_full = rng.standard_normal(n)
    t_full, _ = timeit(lambda: glu.solve(b_full))

    def bench_pattern(pattern, label):
        pattern = np.asarray(sorted(pattern), dtype=np.int64)
        b = np.zeros(n)
        b[pattern] = rng.standard_normal(len(pattern))
        # one-time scheduling cost (cached afterwards; the contract is many
        # solves per excitation pattern)
        solver._sparse_schedules.clear()
        t0 = time.perf_counter()
        _, _, _, breach = solver.schedule_for_pattern(glu.row_map[pattern])
        t_sched = time.perf_counter() - t0
        t_dense, x_ref = timeit(lambda: glu.solve(b))
        t_pruned, x = timeit(lambda: glu.solve(b, rhs_pattern=pattern))
        assert np.array_equal(x_ref, x)          # bit-identical contract
        speedup = t_dense / t_pruned
        row(f"sparse_rhs_{label}", t_pruned * 1e6,
            f"speedup_vs_full={speedup:.2f}x,reach={len(breach)}/{n},"
            f"schedule_once_us={t_sched * 1e6:.0f}")
        return speedup

    # the acceptance shape: a single-entry excitation inside a small domain
    s1 = bench_pattern([1600 + 200], "onehot")
    print(f"# 1-hot pruned trisolve: {s1:.2f}x the full schedule "
          f"(target >= 2x)")

    for d in DENSITIES:
        k = max(1, int(round(d * n)))
        pattern = rng.choice(n, size=k, replace=False)
        bench_pattern(pattern, f"density_{d:g}")

    # many-RHS: K 1-hot seeds against ONE factorization
    seeds = rng.choice(n, size=MULTI_K, replace=False)
    B = np.zeros((MULTI_K, n))
    B[np.arange(MULTI_K), seeds] = 1.0

    def seq():
        return np.stack([glu.solve(B[k]) for k in range(MULTI_K)])

    t_seq, x_seq = timeit(seq)
    t_multi, x_multi = timeit(lambda: glu.solve_multi(B))
    assert np.array_equal(x_seq, x_multi)
    row(f"solve_multi_k{MULTI_K}", t_multi / MULTI_K * 1e6,
        f"speedup_vs_seq={t_seq / t_multi:.2f}x")
    t_multi_p, x_mp = timeit(lambda: glu.solve_multi(B, rhs_pattern=seeds))
    assert np.array_equal(x_multi, x_mp)
    row(f"solve_multi_pruned_k{MULTI_K}", t_multi_p / MULTI_K * 1e6,
        f"speedup_vs_seq={t_seq / t_multi_p:.2f}x")
    print(f"# full solve for reference: {t_full * 1e6:.1f} us")
    return s1


if __name__ == "__main__":
    main()
