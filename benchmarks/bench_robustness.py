"""Robustness-layer overhead: MC64 scaling setup, refined vs plain solve,
and the static-pivot guard's per-factorization cost.

Reports what the robust path costs on a well-conditioned matrix (the
overhead you pay for insurance) and what it buys on an ill-conditioned one
(backward error with/without the layer).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import GLU
from repro.sparse import ill_conditioned_jacobian, make_suite_matrix

from .common import SCALE, row, timeit


def main() -> None:
    import jax.numpy as jnp

    A = make_suite_matrix("rajat12_like", scale=0.3 * SCALE)
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.n)

    t0 = time.perf_counter()
    g_plain = GLU(A, mc64="structural", dtype=jnp.float64)
    setup_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    g_rob = GLU(A, dtype=jnp.float64, static_pivot=1e-10, refine=3)
    setup_rob = time.perf_counter() - t0
    row("setup_structural", setup_plain * 1e6, f"n={A.n}")
    row("setup_mc64_scaled", setup_rob * 1e6,
        f"overhead={setup_rob / max(setup_plain, 1e-12):.2f}x")

    g_plain.factorize()
    g_rob.factorize()
    t, _ = timeit(lambda: g_plain.factorize(), repeats=3)
    row("factorize_plain", t * 1e6, "")
    t, _ = timeit(lambda: g_rob.factorize(), repeats=3)
    row("factorize_guarded", t * 1e6,
        f"growth={g_rob.solve_info['pivot_growth']:.2f}")

    t, _ = timeit(lambda: g_plain.solve(b), repeats=3)
    row("solve_plain", t * 1e6, "")
    t, _ = timeit(lambda: g_rob.solve(b), repeats=3)
    info = g_rob.solve_info
    row("solve_refined", t * 1e6,
        f"iters={info['refine_iters']} berr={info['backward_error']:.1e}")

    # what the layer buys: ill-conditioned instance
    H = ill_conditioned_jacobian(max(150, int(200 * SCALE)), decades=12.0,
                                 seed=3)
    bh = rng.normal(size=H.n)
    gp = GLU(H, mc64="structural", dtype=jnp.float64)
    xp = gp.factorize().solve(bh)
    gr = GLU(H, dtype=jnp.float64, refine=5)
    gr.factorize().solve(bh)
    row("illcond_residual_unscaled", 0.0, f"res={gp.residual(bh, xp):.1e}")
    row("illcond_berr_scaled_refined", 0.0,
        f"berr={gr.solve_info['backward_error']:.1e}")

    # batched refined solve throughput
    B = 8
    batch = np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(B, A.nnz)))
    bs = rng.normal(size=(B, A.n))
    g_rob.factorize_batched(batch)
    t, _ = timeit(lambda: g_rob.solve_batched(bs), repeats=3)
    row("solve_batched_refined", t * 1e6, f"B={B} per_matrix={t / B * 1e6:.1f}us")


if __name__ == "__main__":
    main()
