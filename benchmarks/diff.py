"""Perf-trajectory diff between two committed benchmark artifacts.

Usage:
    python -m benchmarks.diff BENCH_PR6.json BENCH_PR7.json
    python -m benchmarks.diff --latest .          # two newest BENCH_PR*.json
    python -m benchmarks.diff --latest . --gate-prefixes factorize_,ac_,solve_

Compares rows by name and fails (exit 1) when any gated row of the newer
artifact regresses by more than ``--threshold`` (default 1.3x) against the
older one.  Gated rows are those whose name starts with one of the
``--gate-prefixes`` (default ``factorize_`` and ``ac_`` — the repo's
headline factorization numbers plus the batched AC sweep rows every PR is
expected to protect).  Other rows are reported informationally — they carry
too much machine-to-machine noise to gate on.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

DEFAULT_GATE_PREFIXES = ("factorize_", "ac_")


def load_rows(path: str) -> dict:
    """Rows by name; malformed entries (no name / non-numeric us_per_call,
    e.g. from a hand-edited artifact) are warned about and skipped rather
    than crashing the gate."""
    with open(path) as f:
        rows = json.load(f)
    out = {}
    for r in rows:
        name = r.get("name") if isinstance(r, dict) else None
        us = r.get("us_per_call") if isinstance(r, dict) else None
        if not isinstance(name, str) or not isinstance(us, (int, float)):
            print(f"# WARN: {path}: skipping malformed row {r!r}",
                  file=sys.stderr)
            continue
        out[name] = r
    return out


def find_latest_pair(directory: str):
    """The two highest-numbered BENCH_PR<N>.json files in ``directory``."""
    pat = re.compile(r"BENCH_PR(\d+)\.json$")
    found = []
    for p in Path(directory).iterdir():
        m = pat.match(p.name)
        if m:
            found.append((int(m.group(1)), str(p)))
    if len(found) < 2:
        return None
    found.sort()
    return found[-2][1], found[-1][1]


def is_gated(name: str, prefixes=DEFAULT_GATE_PREFIXES) -> bool:
    return any(name.startswith(p) for p in prefixes)


def diff(old_path: str, new_path: str, threshold: float = 1.3,
         gate_prefixes=DEFAULT_GATE_PREFIXES) -> int:
    old = load_rows(old_path)
    new = load_rows(new_path)
    failures = []
    gates = "|".join(f"{p}*" for p in gate_prefixes)
    print(f"# perf diff: {old_path} -> {new_path} "
          f"(gate: {gates} > {threshold:.2f}x)")
    print("name,old_us,new_us,ratio,gated,status")
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        gated = is_gated(name, gate_prefixes)
        if o is None or n is None:
            # one-sided row (benchmark added or removed between artifacts):
            # there is no ratio to gate on, so warn and skip — even for
            # gated prefixes.  A removed gated row is worth a louder look,
            # hence the stderr note rather than silence.
            ou = "-" if o is None else format(o["us_per_call"], ".1f")
            nu = "-" if n is None else format(n["us_per_call"], ".1f")
            status = "added" if o is None else "removed"
            print(f"{name},{ou},{nu},-,{'yes' if gated else 'no'},{status}")
            print(f"# WARN: {name} only in "
                  f"{new_path if o is None else old_path} ({status}); "
                  f"skipped from the gate", file=sys.stderr)
            continue
        ou, nu = o["us_per_call"], n["us_per_call"]
        ratio = nu / ou if ou > 0 else float("inf")
        status = "ok"
        if gated and ratio > threshold:
            status = "REGRESSION"
            failures.append((name, ratio))
        print(f"{name},{ou:.1f},{nu:.1f},{ratio:.2f}x,"
              f"{'yes' if gated else 'no'},{status}")
    if failures:
        print(f"# FAIL: {len(failures)} gated row(s) regressed beyond "
              f"{threshold:.2f}x:", file=sys.stderr)
        for name, ratio in failures:
            print(f"#   {name}: {ratio:.2f}x", file=sys.stderr)
        return 1
    print("# OK: no gated regression")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="benchmarks.diff")
    parser.add_argument("artifacts", nargs="*",
                        help="OLD.json NEW.json (exactly two)")
    parser.add_argument("--latest", metavar="DIR", default=None,
                        help="diff the two highest-numbered BENCH_PR*.json "
                             "in DIR instead of naming files")
    parser.add_argument("--threshold", type=float, default=1.3,
                        help="max allowed new/old ratio on gated rows "
                             "(default 1.3)")
    parser.add_argument("--gate-prefixes", default=",".join(DEFAULT_GATE_PREFIXES),
                        help="comma-separated row-name prefixes to gate on "
                             f"(default {','.join(DEFAULT_GATE_PREFIXES)})")
    args = parser.parse_args(argv)
    prefixes = tuple(p for p in args.gate_prefixes.split(",") if p)
    if args.latest is not None:
        pair = find_latest_pair(args.latest)
        if pair is None:
            print("# fewer than two BENCH_PR*.json artifacts; nothing to diff")
            return 0
        old_path, new_path = pair
    elif len(args.artifacts) == 2:
        old_path, new_path = args.artifacts
    else:
        parser.error("pass OLD.json NEW.json or --latest DIR")
    return diff(old_path, new_path, args.threshold, gate_prefixes=prefixes)


if __name__ == "__main__":
    raise SystemExit(main())
