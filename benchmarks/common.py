"""Shared benchmark utilities."""
from __future__ import annotations

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import numpy as np


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


# every row() call lands here too, so a harness run can dump the whole
# table as machine-readable JSON (benchmarks.run --json out.json)
RESULTS: list = []


def row(name: str, us: float, derived: str = "") -> str:
    RESULTS.append({"name": name, "us_per_call": float(us), "derived": derived})
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


# Benchmark matrix suite — sizes chosen so the full harness finishes on one
# CPU core; pass REPRO_BENCH_SCALE to grow toward paper-scale matrices.
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))

BENCH_MATRICES = [
    ("rajat12_like", 1.0),
    ("circuit_2_like", 0.5),
    ("grid64", 0.5),
    ("memplus_like", 0.1),
    ("asic_like_10k", 0.15),
]


def bench_matrices():
    """Suite matrices AFTER the paper's preprocessing (MC64 + fill-reducing
    ordering, Fig. 5) — levelization/factorization benchmarks measure the
    numeric phase on realistically-ordered patterns, as the paper does."""
    from repro.core import fill_reducing_ordering, zero_free_diagonal
    from repro.sparse import make_suite_matrix

    for name, s in BENCH_MATRICES:
        A = make_suite_matrix(name, scale=s * SCALE)
        rp = zero_free_diagonal(A)
        A = A.permute(rp, np.arange(A.n, dtype=np.int64))
        perm = fill_reducing_ordering(A, "auto")
        yield name, A.permute(perm, perm)
