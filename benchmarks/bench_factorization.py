"""Paper Table I: numeric (re)factorization runtime.

Columns: GLU3.0 (JAX level-parallel executor, fused), the G/P left-looking
sequential baseline (Alg. 1), the hybrid right-looking sequential oracle
(Alg. 2), and scipy's SuperLU (the production CPU reference).  All times are
REfactorization times on a fixed pattern (the SPICE inner loop the paper
measures); symbolic setup is reported separately as "CPU time".
"""
from __future__ import annotations

import time

import numpy as np

from .common import bench_matrices, row, timeit


def main():
    import jax.numpy as jnp
    import scipy.sparse.linalg as spla

    from repro.core import (
        GLU,
        JaxFactorizer,
        build_plan,
        factorize_numpy_fast,
        leftlooking_numpy,
        levelize_relaxed,
        symbolic_fillin,
    )

    print("# table_I: matrix,n,nnz,levels,cpu_setup_ms,glu3_ms,"
          "leftlook_ms,rightlook_ms,scipy_ms,speedup_vs_leftlook")
    out = []
    for name, A in bench_matrices():
        t0 = time.perf_counter()
        As = symbolic_fillin(A, "auto")
        lv = levelize_relaxed(As)
        plan = build_plan(As, lv)
        fx = JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=True)
        setup_ms = (time.perf_counter() - t0) * 1e3

        a_data = np.asarray(A.data)
        t_glu3, vals = timeit(lambda: fx.factorize(a_data).block_until_ready())
        vals0 = As.filled_csc(A).data
        t_ll, _ = timeit(lambda: leftlooking_numpy(As, vals0), repeats=1)
        t_rl, _ = timeit(lambda: factorize_numpy_fast(As, vals0), repeats=1)
        Asp = A.to_scipy().tocsc()
        t_sp, _ = timeit(lambda: spla.splu(Asp, permc_spec="NATURAL",
                                           diag_pivot_thresh=0.0))
        ms = lambda t: t * 1e3
        line = (f"{name},{A.n},{As.nnz},{lv.num_levels},{setup_ms:.0f},"
                f"{ms(t_glu3):.1f},{ms(t_ll):.0f},{ms(t_rl):.0f},{ms(t_sp):.1f},"
                f"{t_ll / t_glu3:.1f}")
        print(line, flush=True)
        row(f"factorize_{name}", t_glu3 * 1e6,
            f"n={A.n} levels={lv.num_levels} groups={fx.n_groups} "
            f"dispatches={fx.last_n_dispatches} "
            f"speedup_vs_GP={t_ll/t_glu3:.1f}x")
        out.append({"matrix": name, "glu3_s": t_glu3, "leftlook_s": t_ll,
                    "rightlook_s": t_rl, "scipy_s": t_sp})
    sp = [o["leftlook_s"] / o["glu3_s"] for o in out]
    print(f"# speedup_vs_leftlooking arithmetic={np.mean(sp):.1f} "
          f"geometric={np.exp(np.mean(np.log(sp))):.1f}")
    return out


if __name__ == "__main__":
    main()
