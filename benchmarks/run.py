# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json out.json`` additionally dumps the same rows as JSON and
# ``--only a,b`` restricts the run to named sections.
from __future__ import annotations

import argparse
import json
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")


def _sections():
    from . import (
        bench_ac,
        bench_batched,
        bench_factorization,
        bench_level_stats,
        bench_levelization,
        bench_modes,
        bench_robustness,
        bench_sparse_rhs,
        bench_sweep_sharded,
        bench_threshold,
        bench_transient,
    )

    return [
        ("levelization",
         "=== Table II: levelization (relaxed vs double-U detection) ===",
         bench_levelization.main),
        ("preprocessing",
         "=== Planner: preprocessing vs numeric breakdown per engine ===",
         bench_levelization.preprocessing_breakdown),
        ("factorization", "=== Table I: numeric factorization ===",
         bench_factorization.main),
        ("modes", "=== Table III: kernel-mode ablation ===", bench_modes.main),
        ("threshold", "=== Fig 12: panel threshold sweep ===",
         bench_threshold.main),
        ("level_stats", "=== Fig 10: level parallelism profile ===",
         bench_level_stats.main),
        ("transient", "=== End-to-end transient (SPICE loop) ===",
         bench_transient.main),
        ("batched",
         "=== Batched refactorization throughput (one plan, B matrices) ===",
         bench_batched.main),
        ("robustness", "=== Robustness layer: scaling / guard / refinement ===",
         bench_robustness.main),
        ("ac", "=== AC sweep: batched complex vs per-frequency loop ===",
         bench_ac.main),
        ("sparse_rhs",
         "=== Sparse-RHS trisolve: reach-pruned vs full schedule ===",
         bench_sparse_rhs.main),
        ("sweep_sharded",
         "=== Sharded sweep scaling (emulated multi-device) ===",
         bench_sweep_sharded.main),
    ]


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(prog="benchmarks.run")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write the result rows as JSON")
    parser.add_argument("--only", metavar="NAMES", default=None,
                        help="comma-separated section names to run "
                             "(default: all)")
    args = parser.parse_args(argv)

    sections = _sections()
    if args.only:
        wanted = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = wanted - {name for name, _, _ in sections}
        if unknown:
            parser.error(f"unknown sections {sorted(unknown)}; available: "
                         f"{[name for name, _, _ in sections]}")
        sections = [s for s in sections if s[0] in wanted]

    from .common import RESULTS

    RESULTS.clear()     # a second in-process main() must not accumulate rows
    print("name,us_per_call,derived")
    for _, header, fn in sections:
        print(f"# {header}")
        fn()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(RESULTS, f, indent=2)
        print(f"# wrote {len(RESULTS)} rows to {args.json}")


if __name__ == "__main__":
    main()
