# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")


def main() -> None:
    from . import (
        bench_batched,
        bench_factorization,
        bench_level_stats,
        bench_levelization,
        bench_modes,
        bench_robustness,
        bench_threshold,
        bench_transient,
    )

    print("name,us_per_call,derived")
    print("# === Table II: levelization (relaxed vs double-U detection) ===")
    bench_levelization.main()
    print("# === Planner: preprocessing vs numeric breakdown per engine ===")
    bench_levelization.preprocessing_breakdown()
    print("# === Table I: numeric factorization ===")
    bench_factorization.main()
    print("# === Table III: kernel-mode ablation ===")
    bench_modes.main()
    print("# === Fig 12: panel threshold sweep ===")
    bench_threshold.main()
    print("# === Fig 10: level parallelism profile ===")
    bench_level_stats.main()
    print("# === End-to-end transient (SPICE loop) ===")
    bench_transient.main()
    print("# === Batched refactorization throughput (one plan, B matrices) ===")
    bench_batched.main()
    print("# === Robustness layer: scaling / guard / refinement ===")
    bench_robustness.main()


if __name__ == "__main__":
    main()
