"""Paper Table II: levelization runtime + level counts.

Compares GLU2.0's exact double-U detection (Alg. 3, the O(n^3)-flavoured
triple scan) against this work's relaxed detection (Alg. 4) — the paper's
headline 2-3 orders of magnitude preprocessing speedup.
"""
from __future__ import annotations

import time

import numpy as np

from .common import bench_matrices, row


def main(rows=None):
    from repro.core import (
        dependencies_doubleu,
        dependencies_relaxed,
        dependencies_upattern,
        levelize,
        levelize_relaxed,
        symbolic_fillin,
    )

    out = []
    print("# table_II: matrix,n,nnz_filled,levels_glu2,levels_glu3,"
          "t_glu2_ms,t_glu3_ms,speedup")
    for name, A in bench_matrices():
        As = symbolic_fillin(A, "auto")

        t0 = time.perf_counter()
        su, du_ = dependencies_upattern(As)
        sd, dd = dependencies_doubleu(As)
        src = np.concatenate([su, sd])
        dst = np.concatenate([du_, dd])
        lv2 = levelize(As.n, src, dst)
        t_glu2 = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        lv3 = levelize_relaxed(As)
        t_glu3 = (time.perf_counter() - t0) * 1e3

        speedup = t_glu2 / t_glu3
        line = (f"{name},{A.n},{As.nnz},{lv2.num_levels},{lv3.num_levels},"
                f"{t_glu2:.1f},{t_glu3:.2f},{speedup:.0f}")
        print(line, flush=True)
        row(f"levelization_{name}", t_glu3 * 1e3,
            f"speedup_over_doubleu={speedup:.0f}x levels_delta="
            f"{lv3.num_levels - lv2.num_levels}")
        out.append({
            "matrix": name, "n": A.n, "nnz": As.nnz,
            "levels_glu2": lv2.num_levels, "levels_glu3": lv3.num_levels,
            "t_glu2_ms": t_glu2, "t_glu3_ms": t_glu3, "speedup": speedup,
        })
    if out:
        sp = [o["speedup"] for o in out]
        print(f"# arithmetic_mean_speedup={np.mean(sp):.0f} "
              f"geometric_mean_speedup={np.exp(np.mean(np.log(sp))):.0f}")
    return out


if __name__ == "__main__":
    main()
