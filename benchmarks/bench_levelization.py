"""Paper Table II: levelization runtime + level counts, plus the planner's
preprocessing-vs-numeric breakdown per symbolic engine.

Compares GLU2.0's exact double-U detection (Alg. 3, the O(n^3)-flavoured
triple scan) against this work's relaxed detection (Alg. 4) — the paper's
headline 2-3 orders of magnitude preprocessing speedup — and, per engine
(gp / etree / vectorized), how the remaining host preprocessing splits
against one device numeric factorization, including the plan-cache-hit
rebuild cost.
"""
from __future__ import annotations

import time

import numpy as np

from .common import bench_matrices, row


def main(rows=None):
    from repro.core import (
        dependencies_doubleu,
        dependencies_relaxed,
        dependencies_upattern,
        levelize,
        levelize_relaxed,
        symbolic_fillin,
    )

    out = []
    print("# table_II: matrix,n,nnz_filled,levels_glu2,levels_glu3,"
          "t_glu2_ms,t_glu3_ms,speedup")
    for name, A in bench_matrices():
        As = symbolic_fillin(A, "auto")

        t0 = time.perf_counter()
        su, du_ = dependencies_upattern(As)
        sd, dd = dependencies_doubleu(As)
        src = np.concatenate([su, sd])
        dst = np.concatenate([du_, dd])
        lv2 = levelize(As.n, src, dst)
        t_glu2 = (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        lv3 = levelize_relaxed(As)
        t_glu3 = (time.perf_counter() - t0) * 1e3

        speedup = t_glu2 / t_glu3
        line = (f"{name},{A.n},{As.nnz},{lv2.num_levels},{lv3.num_levels},"
                f"{t_glu2:.1f},{t_glu3:.2f},{speedup:.0f}")
        print(line, flush=True)
        row(f"levelization_{name}", t_glu3 * 1e3,
            f"speedup_over_doubleu={speedup:.0f}x levels_delta="
            f"{lv3.num_levels - lv2.num_levels}")
        out.append({
            "matrix": name, "n": A.n, "nnz": As.nnz,
            "levels_glu2": lv2.num_levels, "levels_glu3": lv3.num_levels,
            "t_glu2_ms": t_glu2, "t_glu3_ms": t_glu3, "speedup": speedup,
        })
    if out:
        sp = [o["speedup"] for o in out]
        print(f"# arithmetic_mean_speedup={np.mean(sp):.0f} "
              f"geometric_mean_speedup={np.exp(np.mean(np.log(sp))):.0f}")
    return out


def preprocessing_breakdown(engines=("gp", "etree", "vectorized"),
                            gp_limit: int = 6000):
    """Per-engine host preprocessing vs device numeric time.

    For every suite matrix and symbolic engine: the planner's per-stage
    build seconds (ordering / symbolic fill / levelize / plan), one numeric
    factorization on the resulting plan, and the cost of a second, cache-hit
    construction (the transient re-scaling rebuild path).
    """
    import jax

    from repro.core import GLU, PlanCache

    out = []
    print("# preprocessing_breakdown: matrix,engine,n,nnz_filled,levels,"
          "t_order_ms,t_symbolic_ms,t_levelize_ms,t_plan_ms,t_preproc_ms,"
          "t_numeric_ms,t_cached_rebuild_ms")
    for name, A in bench_matrices():
        for engine in engines:
            if engine == "gp" and A.n > gp_limit:
                continue            # per-column python DFS: too slow to time
            cache = PlanCache(capacity=2)
            t0 = time.perf_counter()
            glu = GLU(A, ordering="none", symbolic=engine, mc64="none",
                      plan_cache=cache)
            t_build = (time.perf_counter() - t0) * 1e3
            bs = {k: v * 1e3 for k, v in
                  glu.symbolic_plan.build_seconds.items()}
            vals = np.asarray(A.data)
            glu.factorize(vals)     # warmup: jit compile
            t0 = time.perf_counter()
            jax.block_until_ready(glu.factorize(vals).factorized_values())
            t_num = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            glu2 = GLU(A, ordering="none", symbolic=engine, mc64="none",
                       plan_cache=cache)
            t_cached = (time.perf_counter() - t0) * 1e3
            assert glu2.plan_from_cache and cache.stats.builds == 1
            line = (f"{name},{engine},{A.n},{glu.nnz_filled},"
                    f"{glu.num_levels},{bs['ordering']:.1f},"
                    f"{bs['symbolic']:.1f},{bs['levelize']:.1f},"
                    f"{bs['plan']:.1f},{t_build:.1f},{t_num:.1f},"
                    f"{t_cached:.1f}")
            print(line, flush=True)
            row(f"preproc_{name}_{engine}", bs["total"] * 1e3,
                f"numeric_ms={t_num:.1f} cached_rebuild_ms={t_cached:.1f}")
            out.append({
                "matrix": name, "engine": engine, "n": A.n,
                "nnz_filled": glu.nnz_filled,
                "build_ms": bs, "t_preproc_ms": t_build,
                "t_numeric_ms": t_num, "t_cached_rebuild_ms": t_cached,
            })
    return out


if __name__ == "__main__":
    main()
    preprocessing_breakdown()
