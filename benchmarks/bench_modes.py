"""Paper Table III: kernel-mode ablation.

GLU3.0 adapts execution per level (flat / segmented / panel + scan fusion).
Case 1 disables the flat (type-A) path, Case 2 disables the panel/stream
(type-C) path, Case 3 disables scan fusion entirely (the CUDA-streams
analogue).  Level-type distribution (A/B/C) is reported like the paper.
"""
from __future__ import annotations

import numpy as np

from .common import bench_matrices, row, timeit


def main():
    import jax.numpy as jnp

    from repro.core import JaxFactorizer, build_plan, levelize_relaxed, symbolic_fillin
    from repro.core.plan import MODE_FLAT, MODE_PANEL, MODE_SEGMENTED

    print("# table_III: matrix,glu3_ms,case1_noflat_ms,case2_nopanel_ms,"
          "case3_nofuse_ms,levels_A,levels_B,levels_C")
    out = []
    for name, A in bench_matrices():
        As = symbolic_fillin(A, "auto")
        lv = levelize_relaxed(As)
        plan = build_plan(As, lv)
        a_data = np.asarray(A.data)
        counts = {MODE_FLAT: 0, MODE_SEGMENTED: 0, MODE_PANEL: 0}
        for s in plan.segments:
            counts[s.mode] += 1

        variants = {
            "glu3": dict(),
            "case1_noflat": dict(disable_modes=(MODE_FLAT,)),
            "case2_nopanel": dict(disable_modes=(MODE_PANEL,)),
            "case3_nofuse": dict(fuse_levels=False, jit_schedule=False),
        }
        times = {}
        shape = {}
        for vname, kw in variants.items():
            fx = JaxFactorizer(plan, dtype=jnp.float64, **kw)
            t, _ = timeit(lambda fx=fx: fx.factorize(a_data).block_until_ready())
            times[vname] = t * 1e3
            shape[vname] = (fx.n_groups, fx.last_n_dispatches)
        line = (f"{name},{times['glu3']:.1f},{times['case1_noflat']:.1f},"
                f"{times['case2_nopanel']:.1f},{times['case3_nofuse']:.1f},"
                f"{counts[MODE_FLAT]},{counts[MODE_SEGMENTED]},{counts[MODE_PANEL]}")
        print(line, flush=True)
        g, d = shape["glu3"]
        row(f"modes_{name}", times["glu3"] * 1e3,
            f"groups={g} dispatches={d} "
            f"nofuse_dispatches={shape['case3_nofuse'][1]} "
            f"nofuse_slowdown={times['case3_nofuse']/times['glu3']:.2f}x")
        out.append({"matrix": name, **times, "counts": counts})
    return out


if __name__ == "__main__":
    main()
