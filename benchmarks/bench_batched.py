"""Batched refactorization throughput: one plan, B matrices per dispatch.

Measures per-matrix (re)factorization+solve time as the batch size grows.
The level-group dispatch count is independent of B — each group runs once
for the whole batch — so per-matrix cost falls roughly as the dispatch
overhead amortizes (the CKTSO-style many-matrix workload: Monte-Carlo and
parameter sweeps over one circuit pattern).
"""
from __future__ import annotations

import numpy as np

from .common import row, timeit

BATCH_SIZES = [1, 2, 4, 8]


def main():
    import jax.numpy as jnp

    from repro.core import (
        JaxFactorizer,
        JaxTriangularSolver,
        build_plan,
        fill_reducing_ordering,
        symbolic_fillin,
        zero_free_diagonal,
    )
    from repro.sparse import circuit_jacobian

    A = circuit_jacobian(600, avg_degree=4.5, seed=5)
    rp = zero_free_diagonal(A)
    A = A.permute(rp, np.arange(A.n, dtype=np.int64))
    perm = fill_reducing_ordering(A, "auto")
    A = A.permute(perm, perm)
    As = symbolic_fillin(A, "auto")
    plan = build_plan(As)
    fx = JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=True)
    ts = JaxTriangularSolver(plan)

    rng = np.random.default_rng(0)
    bmax = max(BATCH_SIZES)
    vals_all = np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(bmax, A.nnz)))
    rhs_all = rng.normal(size=(bmax, A.n))

    print(f"# batched_refactorization: n={A.n} nnz_filled={plan.nnz} "
          f"levels={plan.num_levels}")
    print("# batch,us_per_matrix_factorize,us_per_matrix_fact_solve,"
          "throughput_matrices_per_s,speedup_vs_b1")
    per_matrix_b1 = None
    results = []
    for b in BATCH_SIZES:
        batch = vals_all[:b]
        rhs = rhs_all[:b]
        t_fact, _ = timeit(
            lambda: fx.factorize_batched(batch).block_until_ready())
        t_both, _ = timeit(
            lambda: ts.solve_batched(fx.factorize_batched(batch),
                                     rhs).block_until_ready())
        per_matrix = t_fact / b
        if per_matrix_b1 is None:
            per_matrix_b1 = per_matrix
        speedup = per_matrix_b1 / per_matrix
        print(f"{b},{per_matrix * 1e6:.1f},{t_both / b * 1e6:.1f},"
              f"{1.0 / per_matrix:.1f},{speedup:.2f}", flush=True)
        row(f"batched_factorize_b{b}", per_matrix * 1e6,
            f"throughput={1.0 / per_matrix:.1f}/s speedup_vs_b1={speedup:.2f}x")
        results.append({"batch": b, "per_matrix_s": per_matrix,
                        "speedup_vs_b1": speedup})
    b8 = results[-1]
    print(f"# per-matrix throughput at B={b8['batch']}: "
          f"{b8['speedup_vs_b1']:.2f}x the B=1 baseline")
    return results


if __name__ == "__main__":
    main()
