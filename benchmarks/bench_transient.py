"""End-to-end circuit transient benchmark: repeated refactorization (the
paper's target workload) — symbolic once, numeric per Newton iteration."""
from __future__ import annotations

from .common import row


def main():
    from repro.circuit import rc_grid_circuit, transient

    out = []
    for nx in (6, 10):
        ckt = rc_grid_circuit(nx, nx, with_diodes=True, seed=1)
        res = transient(ckt, t_end=0.02, dt=0.002)
        per_fact_ms = res.solve_seconds / max(res.n_factorizations, 1) * 1e3
        row(f"transient_grid{nx}x{nx}", per_fact_ms * 1e3,
            f"factorizations={res.n_factorizations} residual={res.max_residual:.1e}")
        out.append({"grid": nx, "per_fact_ms": per_fact_ms,
                    "n_fact": res.n_factorizations})
    return out


if __name__ == "__main__":
    main()
