"""Sharded batch-sweep scaling: refactorize_solve over 1..8 devices.

Measures the mesh-sharded batched refactorize+solve engine (``GLU(...,
mesh=make_sweep_mesh(d))``) at a fixed batch size while the device count
grows.  Each device count runs in a fresh subprocess because the emulated
host-device topology (``XLA_FLAGS=--xla_force_host_platform_device_count``)
is fixed at jax import time and cannot change within a process.

On a multi-core host the curve shows the shard_map data-parallel speedup;
on a single-core container the emulated devices time-share one core, so
the honest expectation is ~1x (the row notes ``cpu_count`` so readers can
tell which regime produced the numbers).  Every run still asserts the
single-dispatch invariant: each shard executes the whole fused schedule in
ONE device dispatch (``n_dispatches == 1`` and ``solve_dispatches == 1``).

Row names use the ``sweep_sharded_`` prefix, which is intentionally NOT in
the perf-diff gate (``benchmarks.diff`` gates ``factorize_``/``ac_``) —
multi-device emulation timing is far too host-dependent to gate on.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import row

DEVICE_COUNTS = [1, 2, 4, 8]
BATCH = 64
REPEATS = 3
CIRCUIT_N = 600

_ROOT = Path(__file__).resolve().parent.parent


def _child(n_devices: int, batch: int, repeats: int, size: int) -> None:
    """Subprocess body: build one sweep problem, time refactorize_solve.

    Runs with XLA_FLAGS already set by the parent, so jax sees
    ``n_devices`` emulated host devices."""
    import time

    import jax
    import numpy as np

    from repro.core import GLU
    from repro.distributed import make_sweep_mesh
    from repro.sparse import circuit_jacobian

    assert jax.device_count() >= n_devices, (
        f"expected >= {n_devices} devices, got {jax.device_count()}")
    mesh = make_sweep_mesh(n_devices) if n_devices > 1 else None

    A = circuit_jacobian(size, avg_degree=4.5, seed=5)
    glu = GLU(A, mesh=mesh)

    rng = np.random.default_rng(0)
    vals = np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(batch, A.nnz)))
    rhs = rng.normal(size=(batch, A.n))

    glu.refactorize_solve(vals, rhs)            # compile + warm up
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        glu.refactorize_solve(vals, rhs)
        ts.append(time.perf_counter() - t0)
    info = glu.solve_info
    print("RESULT " + json.dumps({
        "elapsed_s": min(ts),
        "n_devices": info["n_devices"],
        "batch_spec": info["batch_spec"],
        "n_dispatches": info["n_dispatches"],
        "solve_dispatches": info["solve_dispatches"],
        "n": A.n,
        "nnz_filled": glu.nnz_filled,
    }), flush=True)


def _run_child(n_devices: int, batch: int, repeats: int, size: int) -> dict:
    env = os.environ.copy()
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["JAX_ENABLE_X64"] = "1"
    src = str(_ROOT / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.bench_sweep_sharded",
           "--child", str(n_devices), str(batch), str(repeats), str(size)]
    proc = subprocess.run(cmd, cwd=str(_ROOT), env=env,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded-sweep child (d={n_devices}) failed:\n{proc.stderr}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(
        f"sharded-sweep child (d={n_devices}) printed no RESULT line:\n"
        f"{proc.stdout}")


def main(smoke: bool = False):
    counts = [1, 2] if smoke else DEVICE_COUNTS
    batch = 8 if smoke else BATCH
    repeats = 1 if smoke else REPEATS
    size = 200 if smoke else CIRCUIT_N
    cores = os.cpu_count() or 1

    print(f"# sweep_sharded: B={batch} refactorize_solve, emulated host "
          f"devices (physical cores: {cores})")
    if cores < max(counts):
        print(f"# NOTE: {cores} core(s) < {max(counts)} devices — emulated "
              f"shards time-share cores; expect ~1x, not linear scaling")
    print("# devices,us_per_matrix,speedup_vs_d1,n_dispatches")

    per_matrix_d1 = None
    results = []
    for d in counts:
        r = _run_child(d, batch, repeats, size)
        assert r["n_devices"] == d, r
        assert r["n_dispatches"] == 1, r
        assert r["solve_dispatches"] == 1, r
        per_matrix = r["elapsed_s"] / batch
        if per_matrix_d1 is None:
            per_matrix_d1 = per_matrix
        speedup = per_matrix_d1 / per_matrix
        print(f"{d},{per_matrix * 1e6:.1f},{speedup:.2f},1", flush=True)
        row(f"sweep_sharded_d{d}", per_matrix * 1e6,
            f"batch={batch} speedup_vs_d1={speedup:.2f}x "
            f"spec={r['batch_spec']} dispatches=1 cores={cores}")
        results.append({"devices": d, "per_matrix_s": per_matrix,
                        "speedup_vs_d1": speedup})
    best = max(results, key=lambda r: r["speedup_vs_d1"])
    print(f"# best scaling: {best['speedup_vs_d1']:.2f}x at "
          f"{best['devices']} devices (single-dispatch held on every run)")
    return results


if __name__ == "__main__":
    if "--child" in sys.argv:
        i = sys.argv.index("--child")
        d, b, r, n = (int(v) for v in sys.argv[i + 1:i + 5])
        _child(d, b, r, n)
    else:
        main(smoke="--smoke" in sys.argv)
