"""Paper Fig. 12: panel ("stream") mode threshold sweep.

The paper found N=16 optimal for when stream mode engages; we sweep the
panel_threshold of the plan's mode chooser the same way.
"""
from __future__ import annotations

import numpy as np

from .common import bench_matrices, row, timeit


def main():
    import jax.numpy as jnp

    from repro.core import JaxFactorizer, build_plan, levelize_relaxed, symbolic_fillin

    thresholds = [5, 8, 16, 32, 64]
    print("# fig12: matrix," + ",".join(f"N{t}_ms" for t in thresholds))
    out = []
    for name, A in bench_matrices():
        As = symbolic_fillin(A, "auto")
        lv = levelize_relaxed(As)
        a_data = np.asarray(A.data)
        times = []
        for th in thresholds:
            plan = build_plan(As, lv, panel_threshold=th)
            fx = JaxFactorizer(plan, dtype=jnp.float64)
            t, _ = timeit(lambda fx=fx: fx.factorize(a_data).block_until_ready())
            times.append(t * 1e3)
        print(f"{name}," + ",".join(f"{t:.1f}" for t in times), flush=True)
        best = thresholds[int(np.argmin(times))]
        row(f"threshold_{name}", min(times) * 1e3, f"best_N={best}")
        out.append({"matrix": name, "thresholds": thresholds, "times_ms": times})
    return out


if __name__ == "__main__":
    main()
