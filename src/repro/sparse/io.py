"""MatrixMarket IO so real UFL/SuiteSparse matrices drop into the benchmarks."""
from __future__ import annotations

import gzip
from pathlib import Path

import numpy as np

from .csc import CSC, csc_from_coo

__all__ = ["read_matrix_market", "write_matrix_market"]


def _open(path):
    path = Path(path)
    if path.suffix == ".gz":
        return gzip.open(path, "rt")
    return open(path, "r")


def read_matrix_market(path) -> CSC:
    with _open(path) as f:
        header = f.readline().strip().lower()
        if not header.startswith("%%matrixmarket"):
            raise ValueError(f"not a MatrixMarket file: {header!r}")
        fields = header.split()
        symmetric = "symmetric" in fields
        pattern = "pattern" in fields
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        nrows, ncols, nnz = (int(x) for x in line.split())
        if nrows != ncols:
            raise ValueError("only square matrices supported")
        rows = np.empty(nnz, dtype=np.int64)
        cols = np.empty(nnz, dtype=np.int64)
        vals = np.empty(nnz, dtype=np.float64)
        for k in range(nnz):
            parts = f.readline().split()
            rows[k] = int(parts[0]) - 1
            cols[k] = int(parts[1]) - 1
            vals[k] = 1.0 if pattern else float(parts[2])
    if symmetric:
        # mirror strictly-off-diagonal entries
        off = rows != cols
        rows, cols, vals = (
            np.concatenate([rows, cols[off]]),
            np.concatenate([cols, rows[off]]),
            np.concatenate([vals, vals[off]]),
        )
    return csc_from_coo(nrows, rows, cols, vals)


def write_matrix_market(path, A: CSC) -> None:
    rows, cols, vals = A.to_coo()
    with open(path, "w") as f:
        f.write("%%MatrixMarket matrix coordinate real general\n")
        f.write(f"{A.n} {A.n} {len(rows)}\n")
        for r, c, v in zip(rows, cols, vals):
            f.write(f"{r + 1} {c + 1} {v:.17g}\n")
