from .csc import CSC, csc_from_coo, csc_to_dense, csc_transpose_pattern
from .gen import (
    SUITES,
    ac_jacobian,
    asic_like,
    circuit_jacobian,
    grid_laplacian,
    ill_conditioned_jacobian,
    make_suite_matrix,
    multi_domain_circuit,
    rc_ladder,
)
from .io import read_matrix_market, write_matrix_market
from .layout import (
    ValueLayout,
    pabs,
    pack_planes,
    pdiv,
    pmul,
    resolve_layout,
    unpack_planes,
)

__all__ = [
    "CSC",
    "csc_from_coo",
    "csc_to_dense",
    "csc_transpose_pattern",
    "SUITES",
    "ac_jacobian",
    "asic_like",
    "circuit_jacobian",
    "grid_laplacian",
    "ill_conditioned_jacobian",
    "make_suite_matrix",
    "multi_domain_circuit",
    "rc_ladder",
    "read_matrix_market",
    "write_matrix_market",
    "ValueLayout",
    "resolve_layout",
    "pack_planes",
    "unpack_planes",
    "pmul",
    "pdiv",
    "pabs",
]
