"""Synthetic circuit-matrix generators.

The paper benchmarks on UFL/SuiteSparse circuit matrices (rajat*, ASIC_*,
G3_circuit, ...).  Those files are not available offline, so we generate
matrices with the same structural character:

* near-structurally-symmetric pattern (MNA stamps are symmetric; sources and
  controlled elements break numeric symmetry),
* zero-free, dominant diagonal (conductance stamps),
* low average degree (2-8 nonzeros/column) with a few high-degree
  rows/columns (supply rails, clock nets),
* large, irregular level structure after fill-in.

``sparse/io.py`` reads real MatrixMarket files when present, so UFL matrices
drop in unchanged.
"""
from __future__ import annotations

import numpy as np

from .csc import CSC, csc_from_coo

__all__ = [
    "grid_laplacian",
    "rc_ladder",
    "circuit_jacobian",
    "ill_conditioned_jacobian",
    "ac_jacobian",
    "asic_like",
    "multi_domain_circuit",
    "SUITES",
    "make_suite_matrix",
]


def grid_laplacian(nx: int, ny: int, leak: float = 1e-3, seed: int = 0) -> CSC:
    """2-D resistor-grid conductance matrix (G3_circuit-like).

    Structurally symmetric, diagonally dominant, n = nx*ny.
    """
    rng = np.random.default_rng(seed)
    n = nx * ny
    idx = np.arange(n).reshape(ny, nx)
    rows, cols, vals = [], [], []

    def stamp(a, b, g):
        rows.extend([a, b, a, b])
        cols.extend([a, b, b, a])
        vals.extend([g, g, -g, -g])

    gh = rng.uniform(0.5, 2.0, size=(ny, nx - 1))
    gv = rng.uniform(0.5, 2.0, size=(ny - 1, nx))
    for y in range(ny):
        for x in range(nx - 1):
            stamp(idx[y, x], idx[y, x + 1], gh[y, x])
    for y in range(ny - 1):
        for x in range(nx):
            stamp(idx[y, x], idx[y + 1, x], gv[y, x])
    rows.extend(range(n))
    cols.extend(range(n))
    vals.extend(np.full(n, leak))  # ground leak keeps it non-singular
    return csc_from_coo(n, rows, cols, vals)


def rc_ladder(n: int, seed: int = 0) -> CSC:
    """RC ladder network conductance matrix (tridiagonal, memplus-flavoured)."""
    rng = np.random.default_rng(seed)
    g = rng.uniform(0.5, 2.0, size=n - 1)
    rows, cols, vals = [], [], []
    for i in range(n - 1):
        rows.extend([i, i + 1, i, i + 1])
        cols.extend([i, i + 1, i + 1, i])
        vals.extend([g[i], g[i], -g[i], -g[i]])
    rows.extend(range(n))
    cols.extend(range(n))
    vals.extend(np.full(n, 1e-2))
    return csc_from_coo(n, rows, cols, vals)


def circuit_jacobian(
    n: int,
    avg_degree: float = 4.0,
    n_rails: int = 0,
    rail_fanout: int = 64,
    asym: float = 0.1,
    pattern_asym: float = 0.0,
    seed: int = 0,
) -> CSC:
    """Random circuit-Jacobian-like matrix (rajat*-flavoured).

    Mostly symmetric pattern with ``asym`` fraction of value asymmetry,
    ``pattern_asym`` fraction of structurally one-sided entries (controlled
    sources / transistor stamps), and ``n_rails`` high-degree nodes.
    Diagonally dominant so no-pivot LU is numerically safe (the GLU flow
    relies on MC64+AMD for this on real data).
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    a = rng.integers(0, n, size=m)
    b = rng.integers(0, n, size=m)
    keep = a != b
    a, b = a[keep], b[keep]
    g = rng.uniform(0.1, 1.0, size=len(a))
    if pattern_asym > 0:
        one_sided = rng.uniform(size=len(a)) < pattern_asym
    else:
        one_sided = np.zeros(len(a), dtype=bool)
    two = ~one_sided
    rows = [a, b[two]]
    cols = [b, a[two]]
    vals = [-g, -g[two] * (1.0 - asym * rng.uniform(0, 1, size=two.sum()))]
    # high-degree rail nodes
    for r in range(n_rails):
        node = rng.integers(0, n)
        targets = rng.choice(n, size=min(rail_fanout, n - 1), replace=False)
        targets = targets[targets != node]
        gr = rng.uniform(0.1, 1.0, size=len(targets))
        rows.extend([np.full(len(targets), node), targets])
        cols.extend([targets, np.full(len(targets), node)])
        vals.extend([-gr, -gr])
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = np.concatenate(vals)
    # diagonal = row-sum dominance + leak
    diag = np.full(n, 0.5)
    np.add.at(diag, rows, np.abs(vals))
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.concatenate([vals, diag])
    return csc_from_coo(n, rows, cols, vals)


def ill_conditioned_jacobian(
    n: int,
    decades: float = 12.0,
    avg_degree: float = 4.0,
    tiny_pivots: int = 0,
    seed: int = 0,
) -> CSC:
    """Badly row/column-scaled circuit Jacobian: condition number roughly
    ``10**decades`` times the base matrix's (device models spanning
    femtofarads to kilo-ohms produce exactly this).  The no-pivot LU
    failure mode this models is numeric, not structural — every diagonal
    stays structurally present, but unscaled factorization loses up to
    ``decades`` digits.  ``tiny_pivots`` additionally crushes that many
    diagonals to ~1e-14 of their column max (structurally nonsingular,
    numerically tiny pivots: the case MC64 max-product matching repairs by
    re-matching and the static-pivot guard must survive without it).
    """
    base = circuit_jacobian(n, avg_degree=avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 7)
    r = 10.0 ** rng.uniform(-decades / 2, decades / 2, size=base.n)
    c = 10.0 ** rng.uniform(-decades / 2, decades / 2, size=base.n)
    rows, cols, vals = base.to_coo()
    A = csc_from_coo(base.n, rows, cols, vals * r[rows] * c[cols.astype(np.int64)])
    if tiny_pivots:
        for j in rng.choice(base.n, size=min(tiny_pivots, base.n), replace=False):
            k = A.value_index(int(j), int(j))
            colmax = np.abs(A.col(int(j))[1]).max()
            A.data[k] = np.sign(A.data[k]) * 1e-14 * colmax
    return A


def ac_jacobian(
    n: int,
    omega: float = 1e3,
    avg_degree: float = 4.0,
    cap_coupling: float = 0.25,
    seed: int = 0,
) -> CSC:
    """Complex AC small-signal matrix ``G + jwC`` on a circuit pattern.

    ``G`` is a :func:`circuit_jacobian`; ``C`` puts ground capacitors on
    every diagonal and couples a ``cap_coupling`` fraction of the
    off-diagonal entries (symmetrically signed, like real MNA cap stamps).
    The result is complex128 with the exact sparsity pattern of ``G`` —
    one real matrix and its whole frequency sweep share a symbolic plan.
    """
    G = circuit_jacobian(n, avg_degree=avg_degree, seed=seed)
    rng = np.random.default_rng(seed + 3)
    c = np.zeros(G.nnz)
    cols = np.repeat(np.arange(G.n), np.diff(G.indptr))
    off = G.indices != cols
    pick = off & (rng.uniform(size=G.nnz) < cap_coupling)
    c[pick] = -rng.uniform(1e-4, 1e-3, size=int(pick.sum()))
    diag = np.zeros(G.n)
    np.add.at(diag, G.indices[pick], -c[pick])
    c[G.diag_value_indices()] = diag + rng.uniform(1e-4, 1e-3, size=G.n)
    return CSC(G.n, G.indptr, G.indices, np.asarray(G.data) + 1j * omega * c)


def multi_domain_circuit(
    domain_sizes: tuple = (1600,) + (400,) * 12,
    seed: int = 0,
) -> CSC:
    """Multi-power-domain chip: structurally decoupled subcircuits sharing
    one MNA system (isolated supply domains, replicated macros, chiplets).

    Block-diagonal of :func:`asic_like` blocks — one symbolic plan and one
    numeric factorization cover the whole chip, but the reach closure of a
    localized excitation stays inside its domain.  This is the matrix class
    where sparse-RHS trisolve pruning wins: a 1-hot RHS touches ~one block
    of the factors instead of all of them.  The default mixes one large
    domain with many small ones, as real floorplans do.
    """
    rows, cols, vals = [], [], []
    off = 0
    for k, m in enumerate(domain_sizes):
        B = asic_like(int(m), seed=seed + 13 * k)
        r, c, v = B.to_coo()
        rows.append(r + off)
        cols.append(c + off)
        vals.append(v)
        off += B.n
    return csc_from_coo(off, np.concatenate(rows), np.concatenate(cols),
                        np.concatenate(vals))


def asic_like(n: int, seed: int = 0) -> CSC:
    """ASIC_100ks-flavoured: grid backbone + random long-range couplings."""
    side = max(2, int(np.sqrt(n)))
    base = grid_laplacian(side, side, seed=seed)
    nn = base.n
    rng = np.random.default_rng(seed + 1)
    extra = max(nn // 10, 4)
    a = rng.integers(0, nn, size=extra)
    b = rng.integers(0, nn, size=extra)
    keep = a != b
    a, b = a[keep], b[keep]
    g = rng.uniform(0.05, 0.5, size=len(a))
    r0, c0, v0 = base.to_coo()
    rows = np.concatenate([r0, a, b, a, b])
    cols = np.concatenate([c0, b, a, a, b])
    vals = np.concatenate([v0, -g, -g, g + 0.25, g + 0.25])
    return csc_from_coo(nn, rows, cols, vals)


# Named suite mirroring the paper's Table I matrix list (synthetic stand-ins).
# sizes are scaled down so CPU-hosted benchmarks finish; pass scale>1 to grow.
SUITES = {
    "rajat12_like": ("circuit_jacobian", dict(n=1879, avg_degree=6.9)),
    "circuit_2_like": ("circuit_jacobian", dict(n=4510, avg_degree=4.7, n_rails=4)),
    "memplus_like": ("rc_ladder", dict(n=17758)),
    "rajat27_like": ("circuit_jacobian", dict(n=20640, avg_degree=4.8, n_rails=8)),
    "onetone2_like": ("circuit_jacobian", dict(n=36057 // 4, avg_degree=6.3, n_rails=16, asym=0.4)),
    "grid64": ("grid_laplacian", dict(nx=64, ny=64)),
    "grid128": ("grid_laplacian", dict(nx=128, ny=128)),
    "asic_like_10k": ("asic_like", dict(n=10000)),
}


def make_suite_matrix(name: str, scale: float = 1.0, seed: int = 0) -> CSC:
    kind, kwargs = SUITES[name]
    kwargs = dict(kwargs)
    for key in ("n", "nx", "ny"):
        if key in kwargs:
            kwargs[key] = max(4, int(kwargs[key] * scale))
    kwargs["seed"] = seed
    return {
        "circuit_jacobian": circuit_jacobian,
        "grid_laplacian": grid_laplacian,
        "rc_ladder": rc_ladder,
        "asic_like": asic_like,
    }[kind](**kwargs)
