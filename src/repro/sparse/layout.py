"""Value-storage layouts for the numeric executors.

Two layouts describe how factor values live on device:

* ``native``  — values are stored in their logical dtype.  Real dtypes are
  unaffected; complex dtypes store interleaved re/im (the JAX/XLA complex
  representation).  This is the bit-reference path: it runs the exact jitted
  programs the repo has always run.
* ``planar``  — complex values are stored as SPLIT real/imaginary planes in
  a trailing axis of size 2: a logical ``(..., nnz)`` complex array becomes
  a ``(..., nnz, 2)`` real array (``[..., 0]`` = re, ``[..., 1]`` = im).
  Every kernel then computes the complex multiply-accumulate on real
  operands (4 real MACs + sign; reciprocal via ``conj(d) / (re^2 + im^2)``),
  which is what lets the Pallas TPU kernels — which take no complex
  operands — run SEGMENTED/PANEL levels and the dense tail for complex128.

Planar storage is an executor-internal representation: the ``GLU`` facade
packs on entry and unpacks on exit, so callers always see native complex.

Index machinery is layout-agnostic by construction: gathers/scatters on a
``(nnz, 2)`` array index ROWS, so the same plan index arrays (including the
pad-index-``== nnz`` drop/fill convention) drive both layouts.

Numerical contract: planar division uses the textbook formula
``a * conj(b) / |b|^2``.  Unlike XLA's complex division it does not guard
against overflow of ``|b|^2`` — fine for the factorization values this repo
scales (MC64 bounds entries by 1), documented here so nobody reuses ``pdiv``
on unscaled data with ``|b|`` near sqrt(floatmax).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.lax
import jax.numpy as jnp

__all__ = [
    "ValueLayout",
    "resolve_layout",
    "pack_planes",
    "unpack_planes",
    "pmul",
    "pdiv",
    "pabs",
]

_REAL_OF = {
    np.dtype(np.complex64): np.dtype(np.float32),
    np.dtype(np.complex128): np.dtype(np.float64),
}
_COMPLEX_OF = {v: k for k, v in _REAL_OF.items()}


@dataclasses.dataclass(frozen=True)
class ValueLayout:
    """How factor values of one logical ``dtype`` are stored on device."""

    name: str               # "native" | "planar"
    dtype: np.dtype         # logical value dtype (what callers see)

    @property
    def planar(self) -> bool:
        return self.name == "planar"

    @property
    def storage_dtype(self) -> np.dtype:
        """dtype of the on-device value array (the re/im plane dtype for
        planar complex; the logical dtype otherwise)."""
        if self.planar:
            return _REAL_OF[self.dtype]
        return self.dtype

    def storage_shape(self, *leading) -> tuple:
        """Shape of the value buffer for a logical ``(*leading,)`` array."""
        return tuple(leading) + ((2,) if self.planar else ())


def resolve_layout(layout, dtype) -> ValueLayout:
    """Resolve a layout request against a logical value dtype.

    ``"auto"`` picks ``planar`` for complex dtypes (restoring mode-adaptive
    Pallas execution) and ``native`` for real ones.  ``"planar"`` on a real
    dtype is rejected — real values have no imaginary plane to split.
    """
    if isinstance(layout, ValueLayout):
        layout = layout.name
    dt = np.dtype(dtype)
    is_complex = np.issubdtype(dt, np.complexfloating)
    if layout == "auto":
        layout = "planar" if is_complex else "native"
    if layout not in ("native", "planar"):
        raise ValueError(
            f"layout must be 'native', 'planar' or 'auto', got {layout!r}")
    if layout == "planar" and not is_complex:
        raise ValueError(
            f"layout='planar' requires a complex dtype, got {dt} "
            f"(real values have no imaginary plane)")
    return ValueLayout(layout, dt)


def pack_planes(x, storage_dtype=None):
    """Logical (complex or real) array -> ``(..., 2)`` re/im planes."""
    x = jnp.asarray(x)
    if storage_dtype is None:
        storage_dtype = _REAL_OF.get(np.dtype(x.dtype), np.dtype(x.dtype))
    return jnp.stack([jnp.real(x).astype(storage_dtype),
                      jnp.imag(x).astype(storage_dtype)], axis=-1)


def unpack_planes(x):
    """``(..., 2)`` re/im planes -> native complex array."""
    x = jnp.asarray(x)
    return jax.lax.complex(x[..., 0], x[..., 1])


def pmul(a, b):
    """Planar complex multiply: 4 real multiplies + sign on (..., 2)."""
    ar, ai = a[..., 0], a[..., 1]
    br, bi = b[..., 0], b[..., 1]
    return jnp.stack([ar * br - ai * bi, ar * bi + ai * br], axis=-1)


def pdiv(a, b):
    """Planar complex divide: multiply by conj(b), scale by 1/(re^2+im^2)."""
    ar, ai = a[..., 0], a[..., 1]
    br, bi = b[..., 0], b[..., 1]
    inv = 1.0 / (br * br + bi * bi)
    return jnp.stack([(ar * br + ai * bi) * inv,
                      (ai * br - ar * bi) * inv], axis=-1)


def pabs(a):
    """Planar complex magnitude: hypot over the trailing plane axis."""
    return jnp.hypot(a[..., 0], a[..., 1])
