"""Compressed sparse column (CSC) containers.

The GLU pipeline works on a *static* sparsity pattern: the structure
(``indptr``/``indices``) lives on the host as numpy int32 arrays, while the
numeric values are a flat device array that gets rewritten on every
(re)factorization.  This mirrors the paper's split between CPU symbolic
analysis and GPU numeric factorization.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["CSC", "concat_ranges", "csc_from_coo", "csc_to_dense",
           "csc_transpose_pattern", "pattern_digest"]


def concat_ranges(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Vectorised concatenation of the half-open ranges [starts[i], ends[i]).

    The workhorse of the host-side symbolic passes: gathering many CSC column
    slices in one shot without a python loop.
    """
    counts = (ends - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    nz = counts > 0
    first = offsets[nz]
    starts_nz = starts[nz].astype(np.int64)
    counts_nz = counts[nz]
    out[first] = starts_nz
    out[first[1:]] -= (starts_nz + counts_nz)[:-1] - 1
    return np.cumsum(out)


def pattern_digest(*parts) -> str:
    """Content hash of a sparsity pattern (or any tuple of arrays/strings/
    scalars): the address of a cached symbolic plan."""
    import hashlib

    h = hashlib.sha256()
    for p in parts:
        if isinstance(p, np.ndarray):
            a = np.ascontiguousarray(p)
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        else:
            h.update(repr(p).encode())
        h.update(b"|")
    return h.hexdigest()


@dataclasses.dataclass
class CSC:
    """Column-compressed sparse matrix with host-side structure.

    ``indptr``:  (n+1,) int32 — column start offsets.
    ``indices``: (nnz,) int32 — row indices, sorted ascending within a column.
    ``data``:    (nnz,) float or complex — numeric values (numpy or jax array).
    """

    n: int
    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def col(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        """Row indices and values of column ``j``."""
        s, e = int(self.indptr[j]), int(self.indptr[j + 1])
        return self.indices[s:e], self.data[s:e]

    def value_index(self, i: int, j: int) -> int:
        """Flat index into ``data`` of element (i, j); -1 if structurally zero."""
        s, e = int(self.indptr[j]), int(self.indptr[j + 1])
        pos = np.searchsorted(self.indices[s:e], i)
        if pos < e - s and self.indices[s + pos] == i:
            return s + int(pos)
        return -1

    def diag_value_indices(self) -> np.ndarray:
        """Flat data index of each diagonal element (requires zero-free diag)."""
        out = np.empty(self.n, dtype=np.int64)
        for j in range(self.n):
            k = self.value_index(j, j)
            if k < 0:
                raise ValueError(f"structurally zero diagonal at column {j}")
            out[j] = k
        return out

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csc_matrix(
            (np.asarray(self.data), self.indices, self.indptr), shape=(self.n, self.n)
        )

    def copy(self) -> "CSC":
        return CSC(self.n, self.indptr.copy(), self.indices.copy(), np.asarray(self.data).copy())

    def permute(self, row_perm: np.ndarray, col_perm: np.ndarray) -> "CSC":
        """Return P_r @ A @ P_c^T, i.e. new[row_perm[i], col_perm[j]] = old[i, j].

        ``row_perm``/``col_perm`` map old index -> new index.
        """
        coo_r, coo_c, coo_v = self.to_coo()
        return csc_from_coo(self.n, row_perm[coo_r], col_perm[coo_c], coo_v)

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        cols = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        return self.indices.copy(), cols, np.asarray(self.data).copy()


def csc_from_coo(n: int, rows, cols, vals, sum_duplicates: bool = True) -> CSC:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    # preserve floating/complex value dtypes (AC matrices are complex128);
    # anything else (ints, python lists of floats) becomes float64
    vals = np.asarray(vals)
    if not np.issubdtype(vals.dtype, np.inexact):
        vals = vals.astype(np.float64)
    order = np.lexsort((rows, cols))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and len(rows):
        key = cols * n + rows
        uniq, inv = np.unique(key, return_inverse=True)
        out_v = np.zeros(len(uniq), dtype=vals.dtype)
        np.add.at(out_v, inv, vals)
        rows = (uniq % n).astype(np.int32)
        cols = (uniq // n).astype(np.int32)
        vals = out_v
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(indptr, cols.astype(np.int64) + 1, 1)
    indptr = np.cumsum(indptr, dtype=np.int64).astype(np.int32)
    return CSC(n, indptr, rows.astype(np.int32), vals)


def csc_to_dense(A: CSC) -> np.ndarray:
    out = np.zeros((A.n, A.n),
                   dtype=np.result_type(np.asarray(A.data).dtype, np.float64))
    for j in range(A.n):
        idx, v = A.col(j)
        out[idx, j] = np.asarray(v)
    return out


def csc_transpose_pattern(n: int, indptr: np.ndarray, indices: np.ndarray):
    """CSR view of a CSC pattern (row-compressed): returns (indptr_t, indices_t, pos_t).

    ``pos_t[k]`` is the flat CSC data index of the k-th entry of the CSR view,
    letting row-wise scans address the same value array.
    """
    counts = np.bincount(indices, minlength=n)
    indptr_t = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    cols = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
    # stable sort by row; within a row, original (column-ascending) order holds
    order = np.argsort(indices, kind="stable")
    indices_t = cols[order]
    pos_t = order.astype(np.int64)
    return indptr_t, indices_t, pos_t
