from .mna import Circuit, rc_grid_circuit
from .simulate import (
    ACSweepResult,
    TransientResult,
    TransientSweepResult,
    ac_sweep,
    perturbed_copies,
    transient,
    transient_sweep,
)

__all__ = [
    "Circuit",
    "rc_grid_circuit",
    "ACSweepResult",
    "TransientResult",
    "TransientSweepResult",
    "ac_sweep",
    "perturbed_copies",
    "transient",
    "transient_sweep",
]
