from .mna import Circuit, rc_grid_circuit
from .simulate import (
    TransientResult,
    TransientSweepResult,
    perturbed_copies,
    transient,
    transient_sweep,
)

__all__ = [
    "Circuit",
    "rc_grid_circuit",
    "TransientResult",
    "TransientSweepResult",
    "perturbed_copies",
    "transient",
    "transient_sweep",
]
