from .mna import Circuit, rc_grid_circuit
from .simulate import TransientResult, transient

__all__ = ["Circuit", "rc_grid_circuit", "TransientResult", "transient"]
