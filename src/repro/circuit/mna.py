"""Modified nodal analysis (MNA) assembly.

Node 0 is ground and is eliminated.  Supported elements: resistors,
capacitors (backward-Euler companion model), DC/time-varying current
sources, AC small-signal current sources, and diodes (Newton companion
model).  The sparsity pattern is fixed across time steps and Newton
iterations — assembly produces a new value vector on the same pattern,
which is exactly the contract ``GLU.factorize(new_values)`` exposes (the
paper's SPICE use case).

``assemble_ac`` produces the AC small-signal systems ``A(w) = G + jwC``
(complex128) for a whole frequency sweep on that same fixed pattern: one
symbolic plan, one complex value vector per frequency point — the batched
refactorization workload.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..sparse.csc import CSC, csc_from_coo

__all__ = ["Circuit", "rc_grid_circuit"]


@dataclasses.dataclass
class _Stamp:
    rows: np.ndarray   # flat CSC entry position of each stamp contribution
    sign: np.ndarray   # +1 / -1
    elem: np.ndarray   # element index the contribution belongs to


class Circuit:
    """Element-stamp container with fixed-pattern fast assembly."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes          # including ground (node 0)
        self.n = n_nodes - 1            # unknowns
        self.resistors: list[tuple[int, int, float]] = []
        self.capacitors: list[tuple[int, int, float]] = []
        self.isources: list[tuple[int, int, Callable[[float], float]]] = []
        self.ac_isources: list[tuple[int, int, complex]] = []
        self.diodes: list[tuple[int, int, float, float]] = []
        self._pattern: Optional[CSC] = None

    # -- element builders ----------------------------------------------------
    def _invalidate_pattern(self) -> None:
        """Drop the cached pattern/stamp maps: any element added after a
        ``pattern()`` call must be reflected by later assembly (a stale
        cache silently ignored post-pattern ``add_*`` calls)."""
        self._pattern = None

    def add_resistor(self, a: int, b: int, ohms: float) -> None:
        self.resistors.append((a, b, 1.0 / ohms))
        self._invalidate_pattern()

    def add_capacitor(self, a: int, b: int, farads: float) -> None:
        self.capacitors.append((a, b, farads))
        self._invalidate_pattern()

    def add_current_source(self, a: int, b: int, i_fn) -> None:
        """Current flows from node a to node b through the source."""
        fn = i_fn if callable(i_fn) else (lambda t, v=float(i_fn): v)
        self.isources.append((a, b, fn))
        self._invalidate_pattern()

    def add_ac_current_source(self, a: int, b: int, phasor=1.0) -> None:
        """Small-signal excitation for AC analysis: a current phasor
        flowing from node a to node b.  Ignored by transient assembly
        (AC sources are zero at the DC operating point by definition)."""
        self.ac_isources.append((a, b, complex(phasor)))
        self._invalidate_pattern()

    def add_diode(self, a: int, b: int, i_sat: float = 1e-12, v_t: float = 0.02585) -> None:
        self.diodes.append((a, b, i_sat, v_t))
        self._invalidate_pattern()

    # -- pattern -------------------------------------------------------------
    def _conductance_pairs(self):
        pairs = [(a, b) for a, b, _ in self.resistors]
        pairs += [(a, b) for a, b, _ in self.capacitors]
        pairs += [(a, b, ) for a, b, *_ in self.diodes]
        return pairs

    def pattern(self) -> CSC:
        """Union sparsity pattern of all stamps (values = small placeholder)."""
        if self._pattern is not None:
            return self._pattern
        rows, cols = [], []
        for a, b in self._conductance_pairs():
            for (x, y) in ((a, a), (b, b), (a, b), (b, a)):
                if x > 0 and y > 0:
                    rows.append(x - 1)
                    cols.append(y - 1)
        # keep the diagonal structurally present for every node
        rows.extend(range(self.n))
        cols.extend(range(self.n))
        vals = np.ones(len(rows), dtype=np.float64)
        self._pattern = csc_from_coo(self.n, rows, cols, vals)
        # value placeholder 1.0 is irrelevant; only structure is used
        self._build_stamp_maps()
        return self._pattern

    def _entry_pos(self, i: int, j: int) -> int:
        p = self._pattern.value_index(i, j)
        assert p >= 0
        return p

    def _build_stamp_maps(self) -> None:
        """Precompute flat positions for each element's 4-point stamp."""
        def quad_positions(pairs):
            pos, sign, elem = [], [], []
            for e, (a, b) in enumerate(pairs):
                for (x, y, s) in ((a, a, 1.0), (b, b, 1.0), (a, b, -1.0), (b, a, -1.0)):
                    if x > 0 and y > 0:
                        pos.append(self._entry_pos(x - 1, y - 1))
                        sign.append(s)
                        elem.append(e)
            return _Stamp(np.asarray(pos, np.int64), np.asarray(sign), np.asarray(elem, np.int64))

        self._r_stamp = quad_positions([(a, b) for a, b, _ in self.resistors])
        self._c_stamp = quad_positions([(a, b) for a, b, _ in self.capacitors])
        self._d_stamp = quad_positions([(a, b) for a, b, *_ in self.diodes])

    # -- assembly --------------------------------------------------------------
    @staticmethod
    def _diode_vd(a: int, b: int, v: np.ndarray) -> float:
        """Clipped diode junction voltage at iterate ``v`` (the clip window
        keeps exp() finite during Newton transients)."""
        va = v[a - 1] if a > 0 else 0.0
        vb = v[b - 1] if b > 0 else 0.0
        return float(np.clip(va - vb, -5.0, 0.8))

    @staticmethod
    def _diode_gd(vd: float, isat: float, vt: float) -> float:
        """Companion-model conductance Gd = Is/Vt exp(vd/Vt) — shared by the
        transient Newton stamps and the AC small-signal linearization."""
        return isat / vt * np.exp(vd / vt)

    def assemble(self, v: np.ndarray, v_prev: np.ndarray, dt: float, t: float):
        """Values (CSC entry order) + rhs for one Newton iterate at time t.

        ``v`` is the current Newton iterate of node voltages (ground
        excluded), ``v_prev`` the solution at the previous time point.
        """
        pat = self.pattern()
        vals = np.zeros(pat.nnz, dtype=np.float64)
        rhs = np.zeros(self.n, dtype=np.float64)

        def vnode(x, arr):
            return arr[x - 1] if x > 0 else 0.0

        # resistors
        if self.resistors:
            g = np.asarray([g for *_ab, g in self.resistors])
            st = self._r_stamp
            np.add.at(vals, st.rows, st.sign * g[st.elem])
        # capacitors (backward Euler): Geq = C/dt, Ieq = Geq * v_prev(a,b)
        if self.capacitors and dt > 0:
            c = np.asarray([c for *_ab, c in self.capacitors])
            geq = c / dt
            st = self._c_stamp
            np.add.at(vals, st.rows, st.sign * geq[st.elem])
            for e, (a, b, _) in enumerate(self.capacitors):
                vab = vnode(a, v_prev) - vnode(b, v_prev)
                ieq = geq[e] * vab
                if a > 0:
                    rhs[a - 1] += ieq
                if b > 0:
                    rhs[b - 1] -= ieq
        # diodes (Newton companion): Gd = Is/Vt exp(vd/Vt), Ieq = Id - Gd vd
        if self.diodes:
            gd = np.empty(len(self.diodes))
            for e, (a, b, isat, vt) in enumerate(self.diodes):
                vd = self._diode_vd(a, b, v)
                g = self._diode_gd(vd, isat, vt)
                i_d = g * vt - isat      # = Is (exp(vd/Vt) - 1), one exp
                gd[e] = g
                ieq = i_d - g * vd
                if a > 0:
                    rhs[a - 1] -= ieq
                if b > 0:
                    rhs[b - 1] += ieq
            st = self._d_stamp
            np.add.at(vals, st.rows, st.sign * gd[st.elem])
        # current sources
        for a, b, fn in self.isources:
            i = fn(t)
            if a > 0:
                rhs[a - 1] -= i
            if b > 0:
                rhs[b - 1] += i
        return vals, rhs

    def assemble_ac(self, v_op: np.ndarray, freqs):
        """AC small-signal systems ``A(w) = G + jwC`` for a frequency sweep.

        ``v_op`` is the DC operating point (ground excluded): resistors and
        the diode companion conductances linearized there stamp ``G``,
        capacitors stamp ``C`` (the physical farads, not the backward-Euler
        ``C/dt``), and the AC current sources build the complex excitation.
        Returns ``(vals, rhs)``: ``vals`` is (F, nnz) complex128 — one value
        vector per frequency on the SAME pattern transient assembly uses —
        and ``rhs`` is (F, n) complex128 (frequency-independent phasors,
        broadcast per point).
        """
        pat = self.pattern()
        omega = 2.0 * np.pi * np.atleast_1d(np.asarray(freqs, dtype=np.float64))
        g_vals = np.zeros(pat.nnz, dtype=np.float64)
        c_vals = np.zeros(pat.nnz, dtype=np.float64)

        if self.resistors:
            g = np.asarray([g for *_ab, g in self.resistors])
            st = self._r_stamp
            np.add.at(g_vals, st.rows, st.sign * g[st.elem])
        if self.diodes:
            # small-signal conductance at the operating point: the same
            # companion-model Gd the transient Newton stamps use
            gd = np.empty(len(self.diodes))
            for e, (a, b, isat, vt) in enumerate(self.diodes):
                gd[e] = self._diode_gd(self._diode_vd(a, b, v_op), isat, vt)
            st = self._d_stamp
            np.add.at(g_vals, st.rows, st.sign * gd[st.elem])
        if self.capacitors:
            c = np.asarray([c for *_ab, c in self.capacitors])
            st = self._c_stamp
            np.add.at(c_vals, st.rows, st.sign * c[st.elem])

        vals = g_vals[None, :] + 1j * omega[:, None] * c_vals[None, :]
        rhs1 = np.zeros(self.n, dtype=np.complex128)
        for a, b, phasor in self.ac_isources:
            if a > 0:
                rhs1[a - 1] -= phasor
            if b > 0:
                rhs1[b - 1] += phasor
        rhs = np.broadcast_to(rhs1, (len(omega), self.n)).copy()
        return vals, rhs


def rc_grid_circuit(nx: int, ny: int, with_diodes: bool = True, seed: int = 0) -> Circuit:
    """Power-grid-flavoured test circuit: resistor mesh, capacitors to ground,
    switching current loads, and clamp diodes on a subset of nodes."""
    rng = np.random.default_rng(seed)
    n_nodes = nx * ny + 1
    ckt = Circuit(n_nodes)
    node = lambda x, y: 1 + y * nx + x
    for y in range(ny):
        for x in range(nx):
            if x + 1 < nx:
                ckt.add_resistor(node(x, y), node(x + 1, y), float(rng.uniform(0.5, 2.0)))
            if y + 1 < ny:
                ckt.add_resistor(node(x, y), node(x, y + 1), float(rng.uniform(0.5, 2.0)))
            ckt.add_resistor(node(x, y), 0, float(rng.uniform(50.0, 200.0)))
            ckt.add_capacitor(node(x, y), 0, float(rng.uniform(1e-3, 5e-3)))
    # switching loads on a few nodes
    for _ in range(max(2, nx * ny // 16)):
        tgt = int(rng.integers(1, n_nodes))
        amp = float(rng.uniform(0.05, 0.2))
        freq = float(rng.uniform(1.0, 5.0))
        ckt.add_current_source(tgt, 0, lambda t, a=amp, f=freq: a * (np.sin(2 * np.pi * f * t) > 0))
    if with_diodes:
        for _ in range(max(1, nx * ny // 32)):
            tgt = int(rng.integers(1, n_nodes))
            ckt.add_diode(tgt, 0)
    return ckt
