"""Adaptive refactorization ladder: per-step recovery-escalation policy.

GLU3.0's premise is that the numeric phase repeats many times on one
symbolic plan, so the right response to a degraded factorization is the
CHEAPEST one that restores health — not an unconditional full rebuild.
Following CKTSO's per-step adaptivity (arXiv 2411.14082), the drivers in
:mod:`repro.circuit.simulate` climb a four-rung ladder:

  rung 0  ``refactorize``  numeric refactorization on the existing plan and
                           scaling — the normal per-iterate action (free).
  rung 1  ``rescale``      rebuild with a fresh MC64 matching/scaling
                           computed on the CURRENT values (the operating
                           point drifted away from what setup-time scaling
                           saw).  Symbolic plan is a cache hit.
  rung 2  ``bump``         rung 1 plus the SuperLU_DIST-style static pivot
                           guard (|diag| < eps * max|A| bumped to the
                           threshold).  Still a plan-cache hit — the guard
                           is a numeric-phase knob, not a symbolic one.
  rung 3  ``replan``       full symbolic replan from scratch (bypassing the
                           plan cache), with rungs 1+2 still applied — the
                           last resort when the cached analysis itself is
                           suspected.

The rung is STICKY and monotonic: once the ladder escalates, later rebuilds
within the same run use at least that rung (the condition that forced the
climb — an operating point the original scaling can't handle — rarely goes
away mid-run, and oscillating between configurations would thrash the
Newton loop).  Because the driver keeps using the rebuilt solver object,
stickiness costs nothing while the run stays healthy: no further rebuilds
fire unless diagnostics degrade again.

Diagnostics (:meth:`RefactorizationLadder.diagnose`) are tiered by cost:
a host-side finiteness check of the solution is free; when iterative
refinement ran, its converged flag is read without forcing any deferred
device reductions; only when refinement is off (``check_growth="auto"``)
does the ladder pull ``solve_info``'s pivot-growth / min-diag reductions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["RUNGS", "LadderConfig", "RefactorizationLadder"]

RUNGS = ("refactorize", "rescale", "bump", "replan")


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Thresholds and policy knobs for the escalation ladder.

    ``growth_max``      pivot growth (max|LU|/max|A|) above which an
                        unrefined factorization is declared unhealthy.
    ``min_diag_floor``  post-factorization diagonal magnitudes at or below
                        this are unhealthy (0.0: only exact zeros).
    ``pivot_eps``       relative static-pivot threshold the ``bump`` rung
                        applies (when the run's own static_pivot is larger,
                        the larger value wins).
    ``check_growth``    ``"auto"`` — growth/min-diag checks only when
                        iterative refinement is off (refinement's backward
                        error is the sharper and cheaper signal);
                        ``"always"`` / ``"never"`` force them on/off.
    ``max_rung``        highest rung the ladder may climb to (3 = replan).
    """
    growth_max: float = 1e8
    min_diag_floor: float = 0.0
    pivot_eps: float = 1e-10
    check_growth: str = "auto"
    max_rung: int = 3

    def __post_init__(self):
        if self.check_growth not in ("auto", "always", "never"):
            raise ValueError(f"check_growth must be auto/always/never, "
                             f"got {self.check_growth!r}")
        if not 0 <= self.max_rung < len(RUNGS):
            raise ValueError(f"max_rung must be in [0, {len(RUNGS) - 1}]")


class RefactorizationLadder:
    """Escalation state machine shared by a driver run.

    The driver calls :meth:`note_refactorize` for every plain numeric
    refactorization, :meth:`diagnose` after each solve, and — while
    diagnose keeps returning a reason — :meth:`escalate` +
    :meth:`glu_kwargs` to rebuild the solver one rung up and retry.
    ``counts`` / ``events`` / ``n_full_rebuilds`` are the reporting
    surface the result dataclasses expose.
    """

    def __init__(self, config: Optional[LadderConfig] = None):
        self.config = config or LadderConfig()
        self.rung = 0
        self.counts = {name: 0 for name in RUNGS}
        self.events: list[dict] = []

    @property
    def rung_name(self) -> str:
        return RUNGS[self.rung]

    @property
    def n_full_rebuilds(self) -> int:
        """Solver reconstructions the ladder triggered (rungs 1-3); plain
        rung-0 refactorizations are not rebuilds."""
        return sum(self.counts[name] for name in RUNGS[1:])

    def note_refactorize(self) -> None:
        self.counts["refactorize"] += 1

    def can_escalate(self) -> bool:
        return self.rung < self.config.max_rung

    def escalate(self, step=None, reason: str = "") -> str:
        """Climb one rung (sticky), record the event, return the new rung's
        name.  Raises if already at ``max_rung`` — guard with
        :meth:`can_escalate`."""
        if not self.can_escalate():
            raise RuntimeError("ladder already at max_rung")
        self.rung += 1
        name = self.rung_name
        self.counts[name] += 1
        self.events.append({"step": step, "rung": name, "reason": reason})
        return name

    def retry_at_current_rung(self, step=None, reason: str = "") -> str:
        """Record a rebuild retry at the current (already escalated) rung —
        used when a LATER step degrades again after the ladder climbed."""
        name = self.rung_name
        if self.rung > 0:
            self.counts[name] += 1
        self.events.append({"step": step, "rung": name, "reason": reason})
        return name

    def diagnose(self, glu, x=None) -> Optional[str]:
        """Health check of the latest factorize+solve; returns a reason
        string when recovery should fire, ``None`` when healthy.

        ``x`` is the host-side solution array (any shape) — a NaN/Inf there
        is the cheapest and most damning signal.  Next, a refined solve's
        converged flag (free: no deferred reductions).  Only for unrefined
        solves (under ``check_growth="auto"``) are the pivot-growth /
        min-diag device reductions forced.
        """
        if x is not None and not np.all(np.isfinite(x)):
            return "non-finite solution"
        conv = glu.refine_converged
        if conv is not None:
            if not np.asarray(conv).all():
                return "iterative refinement stalled above tolerance"
            if self.config.check_growth != "always":
                return None
        elif self.config.check_growth == "never":
            return None
        info = glu.solve_info
        if info is None:
            return None
        growth = np.asarray(info["pivot_growth"])
        min_diag = np.asarray(info["min_diag"])
        if np.any(~np.isfinite(growth)) or np.any(growth > self.config.growth_max):
            return (f"pivot growth {float(np.max(growth)):.3g} exceeds "
                    f"{self.config.growth_max:.3g}")
        if np.any(~np.isfinite(min_diag)) or np.any(
                min_diag <= self.config.min_diag_floor):
            return (f"min |diag| {float(np.min(min_diag)):.3g} at or below "
                    f"floor {self.config.min_diag_floor:.3g}")
        return None

    def glu_kwargs(self, base: dict) -> dict:
        """Constructor kwargs for a rebuild at the current rung: ``base``
        (the driver's own GLU options) with the rung's overrides applied."""
        kw = dict(base)
        if self.rung >= 1:
            kw["mc64"] = "scale"
        if self.rung >= 2:
            prev = kw.get("static_pivot")
            kw["static_pivot"] = (self.config.pivot_eps if prev is None
                                  else max(float(prev), self.config.pivot_eps))
        if self.rung >= 3:
            kw["plan_cache"] = None
        return kw
