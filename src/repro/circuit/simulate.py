"""Transient circuit simulation driver: the paper's end-to-end application.

Backward-Euler time stepping with Newton-Raphson at each step.  The GLU
symbolic plan is built ONCE; every Newton iterate only refactorizes new
values on the fixed pattern — the exact workload GLU3.0 accelerates
("the numeric factorization on GPU might be repeated many times when
solving a nonlinear equation with Newton-Raphson method").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.api import GLU
from .mna import Circuit

__all__ = ["TransientResult", "transient"]


@dataclasses.dataclass
class TransientResult:
    times: np.ndarray           # (T,)
    voltages: np.ndarray        # (T, n)
    newton_iters: np.ndarray    # (T,)
    n_factorizations: int
    setup_seconds: float
    solve_seconds: float
    max_residual: float


def transient(
    ckt: Circuit,
    t_end: float,
    dt: float,
    newton_tol: float = 1e-9,
    max_newton: int = 25,
    ordering: str = "auto",
    dtype=None,
    use_pallas: bool = False,
    glu: Optional[GLU] = None,
) -> TransientResult:
    import jax.numpy as jnp

    dtype = dtype or jnp.float64
    pat = ckt.pattern()
    n = ckt.n

    t0 = time.perf_counter()
    v = np.zeros(n)
    vals0, _ = ckt.assemble(v, v, dt, 0.0)
    from ..sparse.csc import CSC

    A0 = CSC(pat.n, pat.indptr, pat.indices, vals0)
    if glu is None:
        glu = GLU(A0, ordering=ordering, dtype=dtype, use_pallas=use_pallas)
    setup_s = time.perf_counter() - t0

    steps = int(round(t_end / dt))
    times = np.arange(1, steps + 1) * dt
    volts = np.zeros((steps, n))
    iters = np.zeros(steps, dtype=np.int64)
    n_fact = 0
    max_res = 0.0

    t0 = time.perf_counter()
    v_prev = v.copy()
    for s, t in enumerate(times):
        v_it = v_prev.copy()
        for it in range(max_newton):
            vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
            glu.factorize(vals)
            n_fact += 1
            v_new = glu.solve(rhs)
            dv = np.abs(v_new - v_it).max()
            v_it = v_new
            if dv < newton_tol:
                break
        iters[s] = it + 1
        # final residual check at the converged point
        vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
        r = np.abs(A_mul(pat, vals, v_it) - rhs).max()
        max_res = max(max_res, float(r))
        volts[s] = v_it
        v_prev = v_it
    solve_s = time.perf_counter() - t0

    return TransientResult(
        times=times,
        voltages=volts,
        newton_iters=iters,
        n_factorizations=n_fact,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_residual=max_res,
    )


def A_mul(pat, vals: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y = A @ x for values on the circuit pattern (host-side check)."""
    y = np.zeros(pat.n)
    cols = np.repeat(np.arange(pat.n), np.diff(pat.indptr))
    np.add.at(y, pat.indices, vals * x[cols])
    return y
