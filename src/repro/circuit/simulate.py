"""Transient circuit simulation driver: the paper's end-to-end application.

Backward-Euler time stepping with Newton-Raphson at each step.  The GLU
symbolic plan is built ONCE; every Newton iterate only refactorizes new
values on the fixed pattern — the exact workload GLU3.0 accelerates
("the numeric factorization on GPU might be repeated many times when
solving a nonlinear equation with Newton-Raphson method").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.api import GLU
from .mna import Circuit

__all__ = ["TransientResult", "TransientSweepResult", "transient",
           "transient_sweep", "perturbed_copies"]


@dataclasses.dataclass
class TransientResult:
    times: np.ndarray           # (T,)
    voltages: np.ndarray        # (T, n)
    newton_iters: np.ndarray    # (T,)
    n_factorizations: int
    setup_seconds: float
    solve_seconds: float
    max_residual: float


def transient(
    ckt: Circuit,
    t_end: float,
    dt: float,
    newton_tol: float = 1e-9,
    max_newton: int = 25,
    ordering: str = "auto",
    dtype=None,
    use_pallas: bool = False,
    glu: Optional[GLU] = None,
) -> TransientResult:
    import jax.numpy as jnp

    dtype = dtype or jnp.float64
    pat = ckt.pattern()
    n = ckt.n

    t0 = time.perf_counter()
    v = np.zeros(n)
    vals0, _ = ckt.assemble(v, v, dt, 0.0)
    from ..sparse.csc import CSC

    A0 = CSC(pat.n, pat.indptr, pat.indices, vals0)
    if glu is None:
        glu = GLU(A0, ordering=ordering, dtype=dtype, use_pallas=use_pallas)
    setup_s = time.perf_counter() - t0

    steps = int(round(t_end / dt))
    times = np.arange(1, steps + 1) * dt
    volts = np.zeros((steps, n))
    iters = np.zeros(steps, dtype=np.int64)
    n_fact = 0
    max_res = 0.0

    t0 = time.perf_counter()
    v_prev = v.copy()
    for s, t in enumerate(times):
        v_it = v_prev.copy()
        for it in range(max_newton):
            vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
            glu.factorize(vals)
            n_fact += 1
            v_new = glu.solve(rhs)
            dv = np.abs(v_new - v_it).max()
            v_it = v_new
            if dv < newton_tol:
                break
        iters[s] = it + 1
        # final residual check at the converged point
        vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
        r = np.abs(A_mul(pat, vals, v_it) - rhs).max()
        max_res = max(max_res, float(r))
        volts[s] = v_it
        v_prev = v_it
    solve_s = time.perf_counter() - t0

    return TransientResult(
        times=times,
        voltages=volts,
        newton_iters=iters,
        n_factorizations=n_fact,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_residual=max_res,
    )


@dataclasses.dataclass
class TransientSweepResult:
    scales: np.ndarray          # (B,) parameter perturbation factors
    times: np.ndarray           # (T,)
    voltages: np.ndarray        # (B, T, n)
    newton_iters: np.ndarray    # (T,) lockstep iterations per time step
    n_batched_factorizations: int
    setup_seconds: float
    solve_seconds: float
    max_residual: float         # worst over sweep copies and time steps


def perturbed_copies(ckt: Circuit, scales) -> list:
    """One circuit per scale factor: all conductances and capacitances
    multiplied by ``s`` (a global process-corner perturbation).  Topology is
    unchanged, so every copy shares the same sparsity pattern — and hence
    one GLU symbolic plan."""
    out = []
    for s in np.asarray(scales, dtype=np.float64):
        c = Circuit(ckt.n_nodes)
        c.resistors = [(a, b, g * s) for a, b, g in ckt.resistors]
        c.capacitors = [(a, b, cap * s) for a, b, cap in ckt.capacitors]
        c.isources = list(ckt.isources)
        c.diodes = list(ckt.diodes)
        out.append(c)
    return out


def transient_sweep(
    ckt: Circuit,
    t_end: float,
    dt: float,
    scales,
    newton_tol: float = 1e-9,
    max_newton: int = 25,
    ordering: str = "auto",
    dtype=None,
    use_pallas: bool = False,
) -> TransientSweepResult:
    """Run B parameter-perturbed copies of ``ckt`` through backward-Euler +
    Newton in lockstep on ONE symbolic plan (the Monte-Carlo / corner-sweep
    workload: same pattern, many value vectors per Newton iterate).

    Each iterate assembles all B Jacobians on the host, then a single
    fused ``GLU.refactorize_solve`` factorizes and solves the whole batch
    on device.  The step's Newton loop ends when every copy converges.
    """
    import jax.numpy as jnp

    dtype = dtype or jnp.float64
    scales = np.atleast_1d(np.asarray(scales, dtype=np.float64))
    ckts = perturbed_copies(ckt, scales)
    B = len(ckts)
    pat = ckts[0].pattern()
    n = ckt.n

    t0 = time.perf_counter()
    v0 = np.zeros(n)
    vals0, _ = ckts[0].assemble(v0, v0, dt, 0.0)
    from ..sparse.csc import CSC

    glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals0),
              ordering=ordering, dtype=dtype, use_pallas=use_pallas)
    setup_s = time.perf_counter() - t0

    steps = int(round(t_end / dt))
    times = np.arange(1, steps + 1) * dt
    volts = np.zeros((B, steps, n))
    iters = np.zeros(steps, dtype=np.int64)
    n_fact = 0
    max_res = 0.0

    def assemble_all(v_it, v_prev, t):
        vals = np.empty((B, pat.nnz))
        rhs = np.empty((B, n))
        for k, c in enumerate(ckts):
            vals[k], rhs[k] = c.assemble(v_it[k], v_prev[k], dt, t)
        return vals, rhs

    t0 = time.perf_counter()
    v_prev = np.zeros((B, n))
    for s, t in enumerate(times):
        v_it = v_prev.copy()
        for it in range(max_newton):
            vals, rhs = assemble_all(v_it, v_prev, float(t))
            v_new = glu.refactorize_solve(vals, rhs)
            n_fact += 1
            dv = np.abs(v_new - v_it).max()
            v_it = v_new
            if dv < newton_tol:
                break
        iters[s] = it + 1
        vals, rhs = assemble_all(v_it, v_prev, float(t))
        for k in range(B):
            r = np.abs(A_mul(pat, vals[k], v_it[k]) - rhs[k]).max()
            max_res = max(max_res, float(r))
        volts[:, s] = v_it
        v_prev = v_it
    solve_s = time.perf_counter() - t0

    return TransientSweepResult(
        scales=scales,
        times=times,
        voltages=volts,
        newton_iters=iters,
        n_batched_factorizations=n_fact,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_residual=max_res,
    )


def A_mul(pat, vals: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y = A @ x for values on the circuit pattern (host-side check)."""
    y = np.zeros(pat.n)
    cols = np.repeat(np.arange(pat.n), np.diff(pat.indptr))
    np.add.at(y, pat.indices, vals * x[cols])
    return y
