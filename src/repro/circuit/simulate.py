"""Transient circuit simulation driver: the paper's end-to-end application.

Backward-Euler time stepping with Newton-Raphson at each step.  The GLU
symbolic plan is built ONCE; every Newton iterate only refactorizes new
values on the fixed pattern — the exact workload GLU3.0 accelerates
("the numeric factorization on GPU might be repeated many times when
solving a nonlinear equation with Newton-Raphson method").

Degraded factorizations are handled by the adaptive refactorization ladder
(:mod:`repro.circuit.ladder`): instead of one blunt re-scaling rebuild, the
drivers escalate refactorize -> re-scale -> static-pivot bump -> full
replan, climbing only as far as the diagnostics demand
(``escalation="rescale"`` selects the pre-ladder single-rebuild behavior,
``"none"`` disables recovery).  Rebuilds construct a fresh ``GLU`` on the
*same* pattern, so the re-scale and bump rungs go through the planner's
content-addressed cache: only the value-dependent matching/scaling is
recomputed, the symbolic plan is a cache hit (``plan_cache_hits`` on the
results counts them); only the last-resort replan rung bypasses the cache.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import numpy as np

from ..core.api import GLU
from .ladder import LadderConfig, RefactorizationLadder
from .mna import Circuit

__all__ = ["ACSweepResult", "TransientResult", "TransientSweepResult",
           "ac_sweep", "transient", "transient_sweep", "perturbed_copies"]


def _empty_ladder_counts() -> dict:
    from .ladder import RUNGS
    return {name: 0 for name in RUNGS}


def _make_ladder(escalation, config: Optional[LadderConfig]):
    if escalation == "ladder":
        return RefactorizationLadder(config)
    if escalation in ("rescale", "none"):
        return None
    raise ValueError(
        f"escalation must be 'ladder', 'rescale' or 'none', got {escalation!r}")


def _worst_index(glu) -> int:
    """Representative copy of a batched factorization for a rebuild: worst
    backward error when refinement ran, else worst pivot growth."""
    info = glu.solve_info or {}
    for key in ("backward_error", "pivot_growth"):
        v = info.get(key)
        if v is not None and np.ndim(v) > 0:
            a = np.asarray(v, dtype=np.float64)
            a = np.where(np.isfinite(a), a, np.inf)
            return int(np.argmax(a))
    return 0


@dataclasses.dataclass
class TransientResult:
    times: np.ndarray           # (T,)
    voltages: np.ndarray        # (T, n)
    newton_iters: np.ndarray    # (T,)
    n_factorizations: int
    setup_seconds: float
    solve_seconds: float
    max_residual: float
    n_rescalings: int = 0       # cache-served scaling rebuilds (rescale/bump rungs)
    plan_cache_hits: int = 0    # GLU constructions served by the plan cache
    n_full_rebuilds: int = 0    # ALL ladder-triggered rebuilds (rungs 1-3)
    ladder_counts: Optional[dict] = None  # per-rung action counts


def transient(
    ckt: Circuit,
    t_end: float,
    dt: float,
    newton_tol: float = 1e-9,
    max_newton: int = 25,
    ordering: str = "auto",
    dtype=None,
    use_pallas: bool = False,
    glu: Optional[GLU] = None,
    refine: Optional[int] = None,
    refine_tol: Optional[float] = None,
    static_pivot: Optional[float] = None,
    mc64="scale",
    escalation: str = "ladder",
    ladder_config: Optional[LadderConfig] = None,
) -> TransientResult:
    """Backward-Euler + Newton transient.  ``refine=None`` (default) leaves
    a prebuilt ``glu``'s own refinement default in charge; an explicit
    integer — including 0 — overrides it per solve.

    ``escalation`` selects the recovery policy consulted after every linear
    solve (only when this driver constructed the GLU itself — a
    caller-supplied ``glu`` is never swapped out):

    * ``"ladder"`` (default): the adaptive ladder of
      :mod:`repro.circuit.ladder` — on an unhealthy diagnosis (stalled
      refinement, non-finite solution, or excessive pivot growth when
      refinement is off) escalate re-scale -> static-pivot bump -> full
      replan, one rung per retry; the rung is sticky across the run and at
      most one top-rung retry fires per time step.  Per-rung counts land in
      ``ladder_counts``; ``n_rescalings`` counts the cache-served scaling
      rebuilds and ``n_full_rebuilds`` all ladder-triggered rebuilds.
    * ``"rescale"``: the pre-ladder behavior — one MC64 re-scaling rebuild
      per time step when refinement reports non-convergence (requires
      ``refine > 0``).
    * ``"none"``: never rebuild.
    """
    import jax.numpy as jnp

    dtype = dtype or jnp.float64
    pat = ckt.pattern()
    n = ckt.n

    t0 = time.perf_counter()
    v = np.zeros(n)
    vals0, _ = ckt.assemble(v, v, dt, 0.0)
    from ..sparse.csc import CSC

    A0 = CSC(pat.n, pat.indptr, pat.indices, vals0)
    glu_kwargs = dict(ordering=ordering, dtype=dtype, use_pallas=use_pallas,
                      refine=refine or 0, refine_tol=refine_tol,
                      static_pivot=static_pivot, mc64=mc64)
    ladder = _make_ladder(escalation, ladder_config)
    # re-scaling rebuilds only apply to a GLU this driver constructed: a
    # caller-prebuilt solver may carry configuration (dense_tail, custom
    # tolerances, ...) that glu_kwargs cannot reproduce, so it is never
    # silently swapped out mid-run
    owns_glu = glu is None
    n_plan_hits = 0
    if owns_glu:
        glu = GLU(A0, **glu_kwargs)
        n_plan_hits += int(glu.plan_from_cache)
    setup_s = time.perf_counter() - t0

    steps = int(round(t_end / dt))
    times = np.arange(1, steps + 1) * dt
    volts = np.zeros((steps, n))
    iters = np.zeros(steps, dtype=np.int64)
    n_fact = 0
    n_rescale = 0
    max_res = 0.0

    t0 = time.perf_counter()
    v_prev = v.copy()
    for s, t in enumerate(times):
        v_it = v_prev.copy()
        rescaled_this_step = False
        for it in range(max_newton):
            vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
            glu.factorize(vals)
            n_fact += 1
            if ladder is not None:
                ladder.note_refactorize()
            # an explicit refine (including 0) wins over a prebuilt glu's
            # own default; None defers to it
            v_new = (glu.solve(rhs) if refine is None
                     else glu.solve(rhs, refine=refine))
            if ladder is not None and owns_glu:
                # escalation ladder: climb one rung per retry while the
                # diagnosis stays unhealthy.  The rung is sticky across the
                # run; once at the top, at most one fresh-values retry per
                # time step (the Newton dv test remains the step's arbiter).
                # A numerically singular iterate (a device switched fully
                # off) aborts the climb instead of crashing the run.
                reason = ladder.diagnose(glu, v_new)
                while reason is not None:
                    if ladder.can_escalate():
                        ladder.escalate(step=s, reason=reason)
                    elif not rescaled_this_step:
                        ladder.retry_at_current_rung(step=s, reason=reason)
                    else:
                        break
                    rescaled_this_step = True
                    try:
                        glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals),
                                  **ladder.glu_kwargs(glu_kwargs))
                    except ValueError:
                        break
                    n_plan_hits += int(glu.plan_from_cache)
                    glu.factorize(vals)
                    n_fact += 1
                    v_new = (glu.solve(rhs) if refine is None
                             else glu.solve(rhs, refine=refine))
                    reason = ladder.diagnose(glu, v_new)
            elif (escalation == "rescale" and refine and owns_glu
                    and not rescaled_this_step):
                # cheap flag read: must not force solve_info's deferred
                # pivot-stat reductions every Newton iterate
                if glu.refine_converged is False:
                    # refinement stalled: the setup-time scaling no longer
                    # fits this operating point — re-run MC64 on the current
                    # Jacobian and retry the solve on the fresh plan.  At
                    # most one rebuild per time step: if the fresh scaling
                    # doesn't help either, repeating the (expensive) host
                    # symbolic pipeline every Newton iterate won't — the
                    # Newton dv test remains the step's arbiter.  A Jacobian
                    # that is numerically singular at this iterate (a device
                    # switched fully off) just skips the rebuild: crashing
                    # a long run would be strictly worse than pre-PR behavior
                    rescaled_this_step = True
                    try:
                        glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals),
                                  **glu_kwargs)
                    except ValueError:
                        pass
                    else:
                        n_rescale += 1
                        n_plan_hits += int(glu.plan_from_cache)
                        glu.factorize(vals)
                        n_fact += 1
                        v_new = glu.solve(rhs)
            dv = np.abs(v_new - v_it).max()
            v_it = v_new
            if dv < newton_tol:
                break
        iters[s] = it + 1
        # final residual check at the converged point
        vals, rhs = ckt.assemble(v_it, v_prev, dt, float(t))
        r = np.abs(A_mul(pat, vals, v_it) - rhs).max()
        max_res = max(max_res, float(r))
        volts[s] = v_it
        v_prev = v_it
    solve_s = time.perf_counter() - t0

    counts = _empty_ladder_counts() if ladder is None else dict(ladder.counts)
    if ladder is not None:
        n_rescale = counts["rescale"] + counts["bump"]
    return TransientResult(
        times=times,
        voltages=volts,
        newton_iters=iters,
        n_factorizations=n_fact,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_residual=max_res,
        n_rescalings=n_rescale,
        plan_cache_hits=n_plan_hits,
        n_full_rebuilds=0 if ladder is None else ladder.n_full_rebuilds,
        ladder_counts=counts,
    )


@dataclasses.dataclass
class TransientSweepResult:
    scales: np.ndarray          # (B,) parameter perturbation factors
    times: np.ndarray           # (T,)
    voltages: np.ndarray        # (B, T, n)
    newton_iters: np.ndarray    # (T,) lockstep iterations per time step
    n_batched_factorizations: int
    setup_seconds: float
    solve_seconds: float
    max_residual: float         # worst over sweep copies and time steps
    n_rescalings: int = 0       # cache-served scaling rebuilds (rescale/bump rungs)
    plan_cache_hits: int = 0    # GLU constructions served by the plan cache
    n_full_rebuilds: int = 0    # ALL ladder-triggered rebuilds (rungs 1-3)
    ladder_counts: Optional[dict] = None  # per-rung action counts
    n_devices: int = 1          # devices the batch axis was sharded over


def perturbed_copies(ckt: Circuit, scales) -> list:
    """One circuit per scale factor: all conductances and capacitances
    multiplied by ``s`` (a global process-corner perturbation).  Topology is
    unchanged, so every copy shares the same sparsity pattern — and hence
    one GLU symbolic plan."""
    out = []
    for s in np.asarray(scales, dtype=np.float64):
        c = Circuit(ckt.n_nodes)
        c.resistors = [(a, b, g * s) for a, b, g in ckt.resistors]
        c.capacitors = [(a, b, cap * s) for a, b, cap in ckt.capacitors]
        c.isources = list(ckt.isources)
        c.ac_isources = list(ckt.ac_isources)
        c.diodes = list(ckt.diodes)
        out.append(c)
    return out


def transient_sweep(
    ckt: Circuit,
    t_end: float,
    dt: float,
    scales,
    newton_tol: float = 1e-9,
    max_newton: int = 25,
    ordering: str = "auto",
    dtype=None,
    use_pallas: bool = False,
    refine: Optional[int] = None,
    refine_tol: Optional[float] = None,
    static_pivot: Optional[float] = None,
    mc64="scale",
    escalation: str = "ladder",
    ladder_config: Optional[LadderConfig] = None,
    mesh=None,
) -> TransientSweepResult:
    """Run B parameter-perturbed copies of ``ckt`` through backward-Euler +
    Newton in lockstep on ONE symbolic plan (the Monte-Carlo / corner-sweep
    workload: same pattern, many value vectors per Newton iterate).

    Each iterate assembles all B Jacobians on the host, then a single
    fused ``GLU.refactorize_solve`` factorizes and solves the whole batch
    on device.  The step's Newton loop ends when every copy converges.

    ``escalation`` follows :func:`transient`: the default ``"ladder"``
    climbs re-scale -> bump -> replan on unhealthy diagnostics, with the
    worst copy of the batch as the rebuild's scaling representative (one
    shared plan, so one representative picks the scaling).

    ``mesh`` shards the scenario (batch) axis of every batched
    refactorize/solve across the mesh's devices (see ``GLU``'s ``mesh``
    parameter); ladder rebuilds inherit it through ``glu_kwargs``.  The
    Newton loop tracks a per-scenario convergence mask: a converged copy's
    Jacobian is no longer re-assembled and its iterate is frozen, so
    convergence of one shard's scenarios never depends on a global
    ``all()`` re-deriving them — the batch still solves as one lockstep
    dispatch until every copy has converged.
    """
    import jax.numpy as jnp

    dtype = dtype or jnp.float64
    scales = np.atleast_1d(np.asarray(scales, dtype=np.float64))
    ckts = perturbed_copies(ckt, scales)
    B = len(ckts)
    pat = ckts[0].pattern()
    n = ckt.n

    t0 = time.perf_counter()
    v0 = np.zeros(n)
    vals0, _ = ckts[0].assemble(v0, v0, dt, 0.0)
    from ..sparse.csc import CSC

    glu_kwargs = dict(ordering=ordering, dtype=dtype, use_pallas=use_pallas,
                      refine=refine or 0, refine_tol=refine_tol,
                      static_pivot=static_pivot, mc64=mc64, mesh=mesh)
    ladder = _make_ladder(escalation, ladder_config)
    glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals0), **glu_kwargs)
    n_plan_hits = int(glu.plan_from_cache)
    setup_s = time.perf_counter() - t0

    steps = int(round(t_end / dt))
    times = np.arange(1, steps + 1) * dt
    volts = np.zeros((B, steps, n))
    iters = np.zeros(steps, dtype=np.int64)
    n_fact = 0
    n_rescale = 0
    max_res = 0.0

    def assemble_all(v_it, v_prev, t):
        vals = np.empty((B, pat.nnz))
        rhs = np.empty((B, n))
        for k, c in enumerate(ckts):
            vals[k], rhs[k] = c.assemble(v_it[k], v_prev[k], dt, t)
        return vals, rhs

    t0 = time.perf_counter()
    v_prev = np.zeros((B, n))
    for s, t in enumerate(times):
        v_it = v_prev.copy()
        rescaled_this_step = False
        # per-scenario convergence mask: once a copy's Newton update drops
        # below tol its Jacobian stops being re-assembled and its iterate is
        # frozen (masked back after each lockstep solve), so one slow copy
        # never makes the converged ones re-derive their solution — the
        # batch itself still solves as ONE dispatch per iterate
        active = np.ones(B, dtype=bool)
        for it in range(max_newton):
            if it == 0:
                vals, rhs = assemble_all(v_it, v_prev, float(t))
            else:
                for k in np.flatnonzero(active):
                    vals[k], rhs[k] = ckts[k].assemble(
                        v_it[k], v_prev[k], dt, float(t))
            v_new = glu.refactorize_solve(vals, rhs)
            n_fact += 1
            if ladder is not None:
                ladder.note_refactorize()
                # same climb policy as ``transient``; the rebuild's scaling
                # representative is the worst copy of the batch
                reason = ladder.diagnose(glu, v_new)
                while reason is not None:
                    if ladder.can_escalate():
                        ladder.escalate(step=s, reason=reason)
                    elif not rescaled_this_step:
                        ladder.retry_at_current_rung(step=s, reason=reason)
                    else:
                        break
                    rescaled_this_step = True
                    worst = _worst_index(glu)
                    try:
                        glu = GLU(CSC(pat.n, pat.indptr, pat.indices,
                                      vals[worst]),
                                  **ladder.glu_kwargs(glu_kwargs))
                    except ValueError:
                        break
                    n_plan_hits += int(glu.plan_from_cache)
                    v_new = glu.refactorize_solve(vals, rhs)
                    n_fact += 1
                    reason = ladder.diagnose(glu, v_new)
            elif escalation == "rescale" and refine and not rescaled_this_step:
                # cheap flag read per iterate; the full solve_info (with its
                # deferred device reductions) is only pulled on the rare
                # rebuild path below
                conv = glu.refine_converged
                if conv is not None and not np.asarray(conv).all():
                    # re-scale on the worst copy's current Jacobian (one
                    # shared plan, so one representative picks the scaling);
                    # at most once per time step, and a numerically singular
                    # representative skips the rebuild — same rationale as
                    # ``transient``
                    info = glu.solve_info
                    worst = int(np.argmax(np.asarray(info["backward_error"])))
                    rescaled_this_step = True
                    try:
                        glu = GLU(CSC(pat.n, pat.indptr, pat.indices,
                                      vals[worst]), **glu_kwargs)
                    except ValueError:
                        pass
                    else:
                        n_rescale += 1
                        n_plan_hits += int(glu.plan_from_cache)
                        v_new = glu.refactorize_solve(vals, rhs)
                        n_fact += 1
            v_new = np.where(active[:, None], v_new, v_it)
            dv_rows = np.abs(v_new - v_it).max(axis=1)
            v_it = v_new
            active &= dv_rows >= newton_tol
            if not active.any():
                break
        iters[s] = it + 1
        vals, rhs = assemble_all(v_it, v_prev, float(t))
        for k in range(B):
            r = np.abs(A_mul(pat, vals[k], v_it[k]) - rhs[k]).max()
            max_res = max(max_res, float(r))
        volts[:, s] = v_it
        v_prev = v_it
    solve_s = time.perf_counter() - t0

    counts = _empty_ladder_counts() if ladder is None else dict(ladder.counts)
    if ladder is not None:
        n_rescale = counts["rescale"] + counts["bump"]
    return TransientSweepResult(
        scales=scales,
        times=times,
        voltages=volts,
        newton_iters=iters,
        n_batched_factorizations=n_fact,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_residual=max_res,
        n_rescalings=n_rescale,
        plan_cache_hits=n_plan_hits,
        n_full_rebuilds=0 if ladder is None else ladder.n_full_rebuilds,
        ladder_counts=counts,
        n_devices=glu.n_devices if B > 1 else 1,
    )


def A_mul(pat, vals: np.ndarray, x: np.ndarray) -> np.ndarray:
    """y = A @ x for values on the circuit pattern (host-side check)."""
    y = np.zeros(pat.n, dtype=np.result_type(vals.dtype, x.dtype, np.float64))
    cols = np.repeat(np.arange(pat.n), np.diff(pat.indptr))
    np.add.at(y, pat.indices, vals * x[cols])
    return y


# --------------------------------------------------------------------------
# AC small-signal analysis
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ACSweepResult:
    freqs: np.ndarray            # (F,) sweep frequencies in Hz
    voltages: np.ndarray         # (F, n) complex node-voltage phasors
    op_point: np.ndarray         # (n,) DC operating point the sweep linearized at
    op_newton_iters: int         # Newton iterations spent finding it
    n_batched_factorizations: int  # batched complex factorize+solve calls (1)
    setup_seconds: float         # operating point + symbolic plan
    solve_seconds: float         # the batched complex linear solve
    max_backward_error: float    # worst componentwise berr over all freqs
    plan_cache_hits: int = 0     # GLU constructions served by the plan cache
    op_converged: bool = True    # DC operating-point Newton loop met newton_tol
    n_full_rebuilds: int = 0     # ladder-triggered rebuilds (DC + AC phases)
    ladder_counts: Optional[dict] = None  # per-rung action counts
    n_devices: int = 1           # devices the frequency axis was sharded over


def ac_sweep(
    ckt: Circuit,
    freqs,
    newton_tol: float = 1e-9,
    max_newton: int = 50,
    ordering: str = "auto",
    use_pallas: bool = False,
    refine: int = 2,
    refine_tol: Optional[float] = None,
    static_pivot: Optional[float] = None,
    mc64="scale",
    escalation: str = "ladder",
    ladder_config: Optional[LadderConfig] = None,
    layout: str = "auto",
    mesh=None,
) -> ACSweepResult:
    """AC small-signal frequency sweep: ``A(w) x(w) = b`` at every point.

    The classic second half of SPICE: find the DC operating point with the
    existing Newton loop (capacitors open, ``dt=0`` assembly), linearize
    there, then factorize ``A(w) = G + jwC`` for ALL F frequency points in
    lockstep — one complex128 symbolic plan, ONE batched
    ``refactorize_solve`` over the (F, nnz) value matrix.  The sparsity
    pattern never changes across frequencies, so the whole sweep is exactly
    the "one plan, many value vectors" contract the batched
    refactorization engine was built for.

    Iterative refinement (default ``refine=2``) runs verbatim on complex
    values — the componentwise backward error is written in terms of
    ``|.|`` — and ``max_backward_error`` reports the worst frequency point
    on the *original* (unscaled) systems.

    The excitation vector is nonzero only at the AC current-source nodes,
    so the batched solve passes that support as ``rhs_pattern`` and the
    initial triangular solves run on the reach-pruned schedule.  One
    escalation ladder (see :func:`transient`) is shared by the DC
    operating-point loop and the AC phase: a rung climbed while finding
    the op point carries into the AC solver's construction.  A
    non-converged op-point Newton loop sets ``op_converged=False`` and
    warns — the sweep would silently linearize at a wrong operating point.

    ``layout`` selects the AC solver's complex value storage: ``"auto"``
    (default) uses planar re/im planes whenever ``use_pallas=True``, which
    keeps mode-adaptive Pallas execution active for the complex systems
    (and stays native otherwise); ``"native"`` forces the flat-XLA
    native-complex reference path.

    ``mesh`` shards the frequency (scenario) axis of the batched AC
    refactorize/solve across the mesh's devices; the single-matrix DC
    operating-point phase always runs on one device.
    """
    import jax.numpy as jnp

    from ..sparse.csc import CSC

    freqs = np.atleast_1d(np.asarray(freqs, dtype=np.float64))
    pat = ckt.pattern()
    n = ckt.n
    ladder = _make_ladder(escalation, ladder_config)

    t0 = time.perf_counter()
    # DC operating point: dt=0 assembly opens the capacitors; the AC
    # sources are zero at the operating point by definition
    v = np.zeros(n)
    glu_dc = None
    n_plan_hits = 0
    op_iters = 0
    dv = np.inf
    dc_kwargs = dict(ordering=ordering, dtype=jnp.float64,
                     use_pallas=use_pallas, refine=refine,
                     refine_tol=refine_tol, static_pivot=static_pivot,
                     mc64=mc64)
    rebuilt_dc = False
    for it in range(max_newton):
        vals, rhs = ckt.assemble(v, v, 0.0, 0.0)
        if glu_dc is None:
            # the operating-point solves get the same robustness options as
            # the AC phase — a bad op point would silently poison the
            # linearization no matter how accurate the AC solves are
            glu_dc = GLU(CSC(pat.n, pat.indptr, pat.indices, vals),
                         **(dc_kwargs if ladder is None
                            else ladder.glu_kwargs(dc_kwargs)))
            n_plan_hits += int(glu_dc.plan_from_cache)
        glu_dc.factorize(vals)
        v_new = glu_dc.solve(rhs)
        if ladder is not None:
            ladder.note_refactorize()
            reason = ladder.diagnose(glu_dc, v_new)
            while reason is not None:
                if ladder.can_escalate():
                    ladder.escalate(step="dc-op", reason=reason)
                elif not rebuilt_dc:
                    ladder.retry_at_current_rung(step="dc-op", reason=reason)
                else:
                    break
                rebuilt_dc = True
                try:
                    glu_dc = GLU(CSC(pat.n, pat.indptr, pat.indices, vals),
                                 **ladder.glu_kwargs(dc_kwargs))
                except ValueError:
                    break
                n_plan_hits += int(glu_dc.plan_from_cache)
                glu_dc.factorize(vals)
                v_new = glu_dc.solve(rhs)
                reason = ladder.diagnose(glu_dc, v_new)
        dv = np.abs(v_new - v).max()
        v = v_new
        op_iters = it + 1
        if dv < newton_tol:
            break
    op_converged = bool(dv < newton_tol)
    if not op_converged:
        warnings.warn(
            f"ac_sweep: DC operating-point Newton loop did not converge in "
            f"{max_newton} iterations (last |dv| = {dv:.3g} >= newton_tol "
            f"= {newton_tol:.3g}); the sweep linearizes at an unconverged "
            f"operating point", RuntimeWarning, stacklevel=2)

    # the AC excitation's nonzero support: reach-pruned triangular solves
    # need b to be EXACTLY zero outside the pattern
    ac_nodes = sorted({node - 1 for a, b, _ in ckt.ac_isources
                       for node in (a, b) if node > 0})
    rhs_pattern = np.asarray(ac_nodes, dtype=np.int64) if ac_nodes else None

    # one complex plan for the whole sweep (MC64 matches/scales on |A(w0)|)
    vals_ac, rhs_ac = ckt.assemble_ac(v, freqs)
    ac_kwargs = dict(ordering=ordering, dtype=jnp.complex128,
                     use_pallas=use_pallas, refine=refine,
                     refine_tol=refine_tol, static_pivot=static_pivot,
                     mc64=mc64, layout=layout, mesh=mesh)
    glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals_ac[0]),
              **(ac_kwargs if ladder is None
                 else ladder.glu_kwargs(ac_kwargs)))
    n_plan_hits += int(glu.plan_from_cache)
    setup_s = time.perf_counter() - t0
    n_batched = 0

    t0 = time.perf_counter()
    x = glu.refactorize_solve(vals_ac, rhs_ac, rhs_pattern=rhs_pattern)
    n_batched += 1
    if ladder is not None:
        ladder.note_refactorize()
        # AC-phase recovery: rebuild on the worst frequency point's values
        # (one shared plan, one representative for the scaling)
        reason = ladder.diagnose(glu, x)
        rebuilt_ac = False
        while reason is not None:
            if ladder.can_escalate():
                ladder.escalate(step="ac", reason=reason)
            elif not rebuilt_ac:
                ladder.retry_at_current_rung(step="ac", reason=reason)
            else:
                break
            rebuilt_ac = True
            worst = _worst_index(glu)
            try:
                glu = GLU(CSC(pat.n, pat.indptr, pat.indices, vals_ac[worst]),
                          **ladder.glu_kwargs(ac_kwargs))
            except ValueError:
                break
            n_plan_hits += int(glu.plan_from_cache)
            x = glu.refactorize_solve(vals_ac, rhs_ac,
                                      rhs_pattern=rhs_pattern)
            n_batched += 1
            reason = ladder.diagnose(glu, x)
    solve_s = time.perf_counter() - t0

    # componentwise backward error on the original systems, all F points in
    # two vectorized scatter-add SpMV passes (pattern indices built once)
    F = len(freqs)
    rows = np.broadcast_to(pat.indices, (F, len(pat.indices)))
    cols = np.repeat(np.arange(pat.n), np.diff(pat.indptr))
    batch = np.arange(F)[:, None]

    def spmv_all(vmat, xmat):
        y = np.zeros((F, n), dtype=np.result_type(vmat.dtype, xmat.dtype))
        np.add.at(y, (batch, rows), vmat * xmat[:, cols])
        return y

    r = spmv_all(vals_ac, x) - rhs_ac
    denom = spmv_all(np.abs(vals_ac), np.abs(x)) + np.abs(rhs_ac)
    max_berr = float(np.where(denom > 0,
                              np.abs(r) / np.where(denom > 0, denom, 1.0),
                              np.where(np.abs(r) > 0, np.inf, 0.0)).max())

    return ACSweepResult(
        freqs=freqs,
        voltages=x,
        op_point=v,
        op_newton_iters=op_iters,
        n_batched_factorizations=n_batched,
        setup_seconds=setup_s,
        solve_seconds=solve_s,
        max_backward_error=max_berr,
        plan_cache_hits=n_plan_hits,
        op_converged=op_converged,
        n_full_rebuilds=0 if ladder is None else ladder.n_full_rebuilds,
        ladder_counts=(_empty_ladder_counts() if ladder is None
                       else dict(ladder.counts)),
        n_devices=glu.n_devices if len(freqs) > 1 else 1,
    )
