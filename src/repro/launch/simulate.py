"""Circuit-simulation driver — the paper's end-to-end application.

  PYTHONPATH=src python -m repro.launch.simulate --nx 8 --ny 8 \
      --t-end 0.05 --dt 0.005
"""
from __future__ import annotations

import argparse

import numpy as np

from ..circuit import rc_grid_circuit, transient


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=8)
    ap.add_argument("--ny", type=int, default=8)
    ap.add_argument("--t-end", type=float, default=0.05)
    ap.add_argument("--dt", type=float, default=0.005)
    ap.add_argument("--no-diodes", action="store_true")
    ap.add_argument("--ordering", default="auto")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ckt = rc_grid_circuit(args.nx, args.ny, with_diodes=not args.no_diodes,
                          seed=args.seed)
    res = transient(ckt, args.t_end, args.dt, ordering=args.ordering,
                    use_pallas=args.pallas)
    print(f"nodes: {args.nx * args.ny}  steps: {len(res.times)}  "
          f"newton: {res.newton_iters.sum()}  factorizations: {res.n_factorizations}")
    print(f"setup {res.setup_seconds:.2f}s  solve {res.solve_seconds:.2f}s  "
          f"max residual {res.max_residual:.2e}")
    assert np.isfinite(res.voltages).all()
    return res


if __name__ == "__main__":
    main()
