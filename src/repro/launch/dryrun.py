import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis and the roofline terms.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, OOM-at-compile or unsupported collective
fails the cell.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
      --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config, list_archs, shape_cells
from ..distributed.sharding import axis_env, make_rules, spec_struct, tree_shardings
from ..models.model import cache_specs, forward_decode, forward_prefill, param_specs
from ..roofline.analysis import analyze, model_flops_for
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_production_mesh

_SPEC = lambda x: (  # noqa: E731
    isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple) and isinstance(x[1], str)
)


def _rep(mesh):
    return NamedSharding(mesh, P())


def opt_config_for(cfg) -> OptConfig:
    # Adafactor for the 100B+ archs (AdamW moments would not fit per chip)
    big = cfg.param_count() > 60e9
    return OptConfig(kind="adafactor" if big else "adamw")


def opt_shardings(o_structs, p_sh, mesh, p_specs=None, rules=None, fsdp=False):
    """m/v mirror the param shardings; Adafactor's factored vr/vc inherit the
    parent param's axes minus the factored-out dim (a replicated (R, d, h)
    stat for a 340B model would not fit)."""
    out = {"step": _rep(mesh)}
    for key in o_structs:
        if key == "step":
            continue
        if key in ("m", "v"):
            out[key] = p_sh
        else:
            drop = -1 if key == "vr" else -2

            def stat_sh(spec, drop=drop):
                shape, dt, axes = spec
                if len(shape) < 2:
                    return _rep(mesh)
                shape2 = tuple(np.delete(np.array(shape), drop))
                axes2 = tuple(a for i, a in enumerate(axes)
                              if i != len(axes) + drop)
                from ..distributed.sharding import sharding_for_spec

                return sharding_for_spec(shape2, axes2, mesh, rules, fsdp)

            out[key] = jax.tree.map(stat_sh, p_specs, is_leaf=_SPEC)
    return out


def _batch_sharding(mesh, B: int, rules=None):
    """Shard batch per rules['batch'] (default (pod,data)); drops trailing
    axes until divisible, replicates as a last resort."""
    want = (rules or {}).get("batch", ("pod", "data")) or ()
    if not isinstance(want, tuple):
        want = (want,)
    axes = tuple(a for a in want if a in mesh.axis_names)
    while axes:
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if B % size == 0:
            return NamedSharding(mesh, P(axes, None))
        axes = axes[:-1]
    return NamedSharding(mesh, P(None, None))


def batch_specs(cfg, shape, mesh, rules):
    B, S = shape.global_batch, shape.seq_len
    structs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    bsh = _batch_sharding(mesh, B, rules)
    sh = {"tokens": bsh, "labels": bsh}
    if cfg.frontend == "audio_stub":
        structs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        sh["frames"] = NamedSharding(mesh, P(bsh.spec[0], None, None))
    if cfg.frontend == "vision_stub":
        structs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        sh["patch_embeds"] = NamedSharding(mesh, P(bsh.spec[0], None, None))
    return structs, sh


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def _build_lowered(cfg, shape, mesh, rules, tcfg: TrainConfig | None = None):
    """Lower the cell's step function (train/prefill/decode) for ``cfg``."""
    specs = param_specs(cfg)
    p_structs = spec_struct(specs)
    p_sh = tree_shardings(specs, mesh, rules, fsdp=cfg.fsdp)
    bsh = _batch_sharding(mesh, shape.global_batch, rules)

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        o_structs = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), p_structs)
        o_sh = opt_shardings(o_structs, p_sh, mesh, p_specs=specs, rules=rules,
                             fsdp=cfg.fsdp)
        b_structs, b_sh = batch_specs(cfg, shape, mesh, rules)
        step = make_train_step(cfg, opt_cfg, tcfg or TrainConfig())
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
        return jitted.lower(p_structs, o_structs, b_structs)
    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        extras = _extras_structs(cfg, B, mesh, bsh)

        def prefill_step(params, tokens, extras=None):
            return forward_prefill(params, tokens, cfg, extras)

        args = (p_structs, tok) + ((extras[0],) if extras else ())
        shs = (p_sh, bsh) + ((extras[1],) if extras else ())
        jitted = jax.jit(prefill_step, in_shardings=shs)
        return jitted.lower(*args)
    # decode
    B, S = shape.global_batch, shape.seq_len
    c_specs = cache_specs(cfg, B, S)
    c_structs = spec_struct(c_specs)
    c_sh = tree_shardings(c_specs, mesh, rules)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def serve_step(params, token, cache):
        return forward_decode(params, token, cache, cfg)

    jitted = jax.jit(serve_step, in_shardings=(p_sh, bsh, c_sh),
                     donate_argnums=(2,))
    return jitted.lower(p_structs, tok, c_structs)


def _pattern_period(cfg) -> int:
    import math as _m

    period = 1
    if cfg.attn_every:
        period = period * cfg.attn_every // _m.gcd(period, cfg.attn_every)
    if cfg.n_experts and cfg.moe_every > 1:
        period = period * cfg.moe_every // _m.gcd(period, cfg.moe_every)
    return period


def _probe_costs(cfg, shape, mesh, rules, tcfg=None):
    """Scan bodies are costed once by HLO cost analysis, so flops/bytes/
    collective counts from the full scanned program understate depth.  Fix:
    compile two shallow UNSCANNED variants (1 and 2 pattern periods) and
    extrapolate linearly in num_layers — exact for the periodic stack, and
    the intercept captures embed/head/loss. Returns (flops, bytes, coll_detail).
    """
    import dataclasses as dc

    from ..models.model import use_scan
    from ..roofline.analysis import collective_bytes_from_hlo

    if not use_scan(cfg):
        return None
    period = _pattern_period(cfg)
    fd = cfg.first_dense
    n1, n2 = fd + period, fd + 2 * period
    if cfg.num_layers <= n2:
        return None
    samples = []
    for n in (n1, n2):
        cfg_n = dc.replace(cfg, num_layers=n, scan_layers=False)
        lowered = _build_lowered(cfg_n, shape, mesh, rules, tcfg)
        compiled = lowered.compile()
        cost = _cost_dict(compiled)
        col = collective_bytes_from_hlo(compiled.as_text())
        samples.append((n, float(cost.get("flops", 0.0)),
                        float(cost.get("bytes accessed", 0.0)),
                        {k: v for k, v in col.items() if k != "_counts"}))
    (na, fa, ba, ca), (nb, fb, bb, cb) = samples
    L = cfg.num_layers

    def extrap(va, vb):
        slope = (vb - va) / (nb - na)
        return max(va + slope * (L - na), 0.0)

    detail = {k: extrap(ca[k], cb[k]) for k in ca}
    return extrap(fa, fb), extrap(ba, bb), detail


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             rules_override: dict | None = None, tag: str = "",
             probe: bool = True, cfg_override: dict | None = None,
             tcfg: TrainConfig | None = None) -> dict:
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_override:
        cfg = _dc.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    rules = make_rules(cfg, **(rules_override or {}))
    if shape_name == "long_500k":
        # context-parallel decode: KV/cache sequence sharded over model axis
        rules["kv_seq"] = "model"

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind, "tag": tag, "ok": False,
    }
    t0 = time.time()
    try:
        with axis_env(mesh, rules):
            lowered = _build_lowered(cfg, shape, mesh, rules, tcfg)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            probe_terms = _probe_costs(cfg, shape, mesh, rules, tcfg) if probe else None

        ms = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()
        mf = model_flops_for(cfg, shape)
        roof = analyze(arch, shape_name, mesh_name, chips, cost, hlo, mf)
        if probe_terms is not None:
            from ..roofline.analysis import Roofline

            flops, nbytes, detail = probe_terms
            roof = Roofline(
                arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
                hlo_flops=flops, hlo_bytes=nbytes,
                collective_bytes=float(sum(detail.values())),
                collective_detail=detail, model_flops=mf,
            ).finalize()
            rec["cost_source"] = "probe-extrapolated"
        else:
            rec["cost_source"] = "exact"
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            memory={
                "argument_bytes": ms.argument_size_in_bytes,
                "output_bytes": ms.output_size_in_bytes,
                "temp_bytes": ms.temp_size_in_bytes,
                "alias_bytes": ms.alias_size_in_bytes,
                "temp_bytes_per_device": ms.temp_size_in_bytes // chips,
                "argument_bytes_per_device": ms.argument_size_in_bytes // chips,
            },
            roofline=roof.to_dict(),
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a report, not a crash
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}_{shape_name}_{mesh_name}{('_' + tag) if tag else ''}.json"
    with open(out_dir / name, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else "FAIL"
    extra = (f" compile={rec.get('compile_s')}s dominant={rec['roofline']['dominant']}"
             if rec["ok"] else f" {rec.get('error', '')[:120]}")
    print(f"[{status}] {arch} {shape_name} {mesh_name}{extra}", flush=True)
    return rec


def _extras_structs(cfg, B, mesh, bsh):
    if cfg.frontend == "audio_stub":
        st = {"frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                             jnp.bfloat16)}
        sh = {"frames": NamedSharding(mesh, P(bsh.spec[0], None, None))}
        return st, sh
    if cfg.frontend == "vision_stub":
        st = {"patch_embeds": jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)}
        sh = {"patch_embeds": NamedSharding(mesh, P(bsh.spec[0], None, None))}
        return st, sh
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    out = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        cells = shape_cells(arch) if args.shape == "all" else [args.shape]
        for shape_name in cells:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                f = out / f"{arch}_{shape_name}_{mesh_name}.json"
                if args.skip_existing and f.exists():
                    rec = json.loads(f.read_text())
                    if rec.get("ok"):
                        print(f"[SKIP] {arch} {shape_name} {mesh_name}")
                        results.append(rec)
                        continue
                results.append(run_cell(arch, shape_name, mp, out))
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
