"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from ..configs import get_config
from ..models.model import init_params
from ..serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=0,
                    help="run the dependency-aware scheduler with N requests")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    extras = None
    if cfg.frontend == "audio_stub":
        extras = {"frames": rng.normal(size=(args.batch, cfg.encoder_seq,
                                             cfg.d_model)).astype(np.float32)}
    if cfg.frontend == "vision_stub":
        extras = {"patch_embeds": rng.normal(size=(args.batch, cfg.frontend_tokens,
                                                   cfg.d_model)).astype(np.float32)}
    engine = ServeEngine(cfg, params, extras)

    if args.requests:
        reqs = []
        for i in range(args.requests):
            parent = i - 1 if i % 3 == 2 else None  # every 3rd extends previous
            reqs.append(Request(
                rid=i,
                tokens=rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                max_new=args.max_new, parent=parent))
        t0 = time.time()
        results = engine.run(reqs, batch_size=args.batch)
        print(f"{len(results)} requests served in {time.time()-t0:.1f}s "
              f"(dependency levels honoured)")
        return results

    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate_batch(prompts, args.max_new)
    dt = time.time() - t0
    tps = args.batch * args.max_new / dt
    print(f"generated {out.shape} in {dt:.1f}s ({tps:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
