"""End-to-end training driver.

Runs anywhere: a reduced config on the host CPU (smoke / the examples) or a
full config on a real mesh (the dry-run proves the production shardings).
Features: deterministic resumable data pipeline, sharded zstd checkpoints
with auto-resume, preemption flush, optional grad compression + microbatch
accumulation.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.pipeline import TokenPipeline
from ..distributed.sharding import axis_env, make_rules, tree_shardings
from ..models.model import init_params, param_specs
from ..train.checkpoint import Checkpointer
from ..train.fault import PreemptionGuard
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_host_mesh


def build_cfg(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    over = {}
    if args.layers:
        over["num_layers"] = args.layers
    if args.d_model:
        over["d_model"] = args.d_model
        over["head_dim"] = max(args.d_model // max(cfg.num_heads, 1), 8)
    if args.d_ff:
        over["d_ff"] = args.d_ff
    if args.vocab:
        over["vocab_size"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg


def extras_fn_for(cfg):
    if cfg.frontend == "audio_stub":
        return lambda rng, b: {
            "frames": rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)}
    if cfg.frontend == "vision_stub":
        return lambda rng, b: {
            "patch_embeds": rng.normal(size=(b, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = build_cfg(args)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    rules = make_rules(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup=min(50, args.steps // 10 + 1),
                        total_steps=args.steps)
    tcfg = TrainConfig(microbatches=args.microbatches,
                       compress_grads=args.compress_grads)

    key = jax.random.PRNGKey(args.seed)
    with axis_env(mesh, rules):
        params = init_params(cfg, key)
        opt_state = init_opt_state(params, opt_cfg)
        specs = param_specs(cfg)
        p_sh = tree_shardings(specs, mesh, rules, fsdp=cfg.fsdp)
        params = jax.tree.map(jax.device_put, params, p_sh)
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, tcfg),
                          donate_argnums=(0, 1))

        pipe = TokenPipeline(cfg.padded_vocab, args.batch, args.seq,
                             seed=args.seed, extras_fn=extras_fn_for(cfg))
        ckpt = Checkpointer(args.ckpt_dir, every=args.ckpt_every) \
            if args.ckpt_dir else None
        start = 0
        if ckpt:
            state, start = ckpt.resume({"params": params, "opt": opt_state})
            if state is not None:
                params = jax.tree.map(jnp.asarray, state["params"])
                opt_state = jax.tree.map(jnp.asarray, state["opt"])
                print(f"resumed from step {start}")
            pipe.skip_to(start)

        history = []
        with PreemptionGuard() as guard:
            t0 = time.time()
            for step in range(start, args.steps):
                batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                if step % args.log_every == 0 or step == args.steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    dt = time.time() - t0
                    print(f"step {step:5d} loss {m['loss']:.4f} nll {m['nll']:.4f} "
                          f"gnorm {m['grad_norm']:.2f} ({dt:.1f}s)", flush=True)
                    history.append({"step": step, **m, "elapsed_s": dt})
                if ckpt:
                    ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                                    force=guard.should_stop)
                if guard.should_stop:
                    print("preemption signal — checkpoint flushed, exiting")
                    break
        if ckpt:
            ckpt.maybe_save(args.steps, {"params": params, "opt": opt_state},
                            force=True)
    if args.metrics_out:
        Path(args.metrics_out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.metrics_out).write_text(json.dumps(history, indent=1))
    return history


if __name__ == "__main__":
    main()
