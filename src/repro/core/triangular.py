"""Level-scheduled sparse triangular solves (Ly = b, Ux = y) in JAX,
plus batched iterative refinement on the device factors.

The forward sweep reuses the factorization levels (its dependency rule —
column j must wait for all c < j with L(j,c) != 0 — is exactly the paper's
"look left" relaxed rule, so the same levelization is valid).  The backward
sweep uses U-row levels computed at plan time.

Refinement runs on whatever system the factors describe (for the GLU facade
that is the scaled + permuted one): each sweep computes ``r = b - A x`` with
a sparse SpMV of A's values, the componentwise backward error
``max_i |r_i| / (|A||x| + |b|)_i`` as the stopping test, and — while above
tolerance — one more triangular solve on the existing factors.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.ops import spmv
from .plan import FactorizePlan

__all__ = ["JaxTriangularSolver", "trisolve_numpy"]


def trisolve_numpy(plan: FactorizePlan, vals: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Sequential oracle: unit-lower forward then upper backward solve."""
    n, indptr, indices = plan.n, plan.indptr, plan.indices
    vals = np.asarray(vals)
    dtype = np.result_type(vals.dtype, np.asarray(b).dtype, np.float64)
    vals = vals.astype(dtype, copy=False)
    x = np.array(b, dtype=dtype, copy=True)
    for j in range(n):
        e = int(indptr[j + 1])
        dp = int(plan.diag_idx[j])
        rows = indices[dp + 1 : e]
        x[rows] -= vals[dp + 1 : e] * x[j]
    for j in range(n - 1, -1, -1):
        s = int(indptr[j])
        dp = int(plan.diag_idx[j])
        x[j] /= vals[dp]
        rows = indices[s:dp]
        x[rows] -= vals[s:dp] * x[j]
    return x


def _pad_i32(x: np.ndarray, size: int, fill: int) -> np.ndarray:
    out = np.full(size, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


def _pow2(x: int, lo: int = 8) -> int:
    return max(lo, 1 << (int(x - 1).bit_length())) if x > 0 else lo


def _fwd_group_body(vals, b, rows, cols, vidx):
    def body(bb, xs):
        r, c, v = xs
        lv = vals.at[v].get(mode="fill", fill_value=0.0)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-lv * xc, mode="drop"), None

    b, _ = jax.lax.scan(body, b, (rows, cols, vidx))
    return b


def _bwd_group_body(vals, b, lcols, ldiag, rows, cols, vidx):
    def body(bb, xs):
        lc, ld, r, c, v = xs
        dv = vals.at[ld].get(mode="fill", fill_value=1.0)
        xj = bb.at[lc].get(mode="fill", fill_value=0.0) / dv
        bb = bb.at[lc].set(xj, mode="drop")
        uv = vals.at[v].get(mode="fill", fill_value=0.0)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-uv * xc, mode="drop"), None

    b, _ = jax.lax.scan(body, b, (lcols, ldiag, rows, cols, vidx))
    return b


def _residual_berr_body(rows, cols, a_vals, a_abs, x, b, n):
    """r = b - A x and the componentwise backward error in one dispatch.
    Zero denominators (a row with |A||x| + |b| == 0) count as converged
    when the residual there is zero and as inf otherwise."""
    r = b - spmv(rows, cols, a_vals, x, n_rows=n)
    denom = spmv(rows, cols, a_abs, jnp.abs(x), n_rows=n) + jnp.abs(b)
    berr = jnp.max(jnp.where(denom > 0, jnp.abs(r) / denom,
                             jnp.where(jnp.abs(r) > 0, jnp.inf, 0.0)))
    return r, berr


@partial(jax.jit, static_argnames=("n",))
def _residual_berr(rows, cols, a_vals, a_abs, x, b, *, n):
    return _residual_berr_body(rows, cols, a_vals, a_abs, x, b, n)


@partial(jax.jit, static_argnames=("n",))
def _residual_berr_batched(rows, cols, a_vals, a_abs, x, b, *, n):
    return jax.vmap(
        lambda av, aa, xx, bb: _residual_berr_body(rows, cols, av, aa, xx, bb, n)
    )(a_vals, a_abs, x, b)


_fwd_group = partial(jax.jit, donate_argnums=(1,))(_fwd_group_body)
_bwd_group = partial(jax.jit, donate_argnums=(1,))(_bwd_group_body)

# Batched twins: vals (B, nnz) and b (B, n) share the level-group index
# arrays, so each group stays ONE dispatch for the whole batch.
_fwd_group_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_fwd_group_body, in_axes=(0, 0, None, None, None)))
_bwd_group_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_bwd_group_body, in_axes=(0, 0, None, None, None, None, None)))


class JaxTriangularSolver:
    """solve(vals, b): forward+backward substitution on the factored values."""

    def __init__(self, plan: FactorizePlan, fuse: bool = True):
        self.plan = plan
        n = plan.n
        pad_row = n  # out-of-range -> drop
        pad_v = plan.nnz

        def build_groups(items):
            groups, run, run_shape = [], [], None

            def flush():
                nonlocal run, run_shape
                if run:
                    groups.append(
                        tuple(jnp.asarray(np.stack([r[i] for r in run]))
                              for i in range(len(run[0])))
                    )
                run, run_shape = [], None

            for arrs, shape in items:
                if fuse and shape == run_shape:
                    run.append(arrs)
                else:
                    flush()
                    run, run_shape = [arrs], shape
            flush()
            return groups

        fwd_items = []
        nlev = len(plan.fwd_ptr) - 1
        for l in range(nlev):
            s, e = int(plan.fwd_ptr[l]), int(plan.fwd_ptr[l + 1])
            p = _pow2(e - s)
            fwd_items.append((
                (
                    _pad_i32(plan.fwd_rows[s:e], p, pad_row),
                    _pad_i32(plan.fwd_cols[s:e], p, pad_row),
                    _pad_i32(plan.fwd_vidx[s:e], p, pad_v),
                ),
                p,
            ))
        self._fwd_groups = build_groups(fwd_items)

        bwd_items = []
        nulev = len(plan.bwd_ptr) - 1
        diag = plan.diag_idx
        for l in range(nulev):
            s, e = int(plan.bwd_ptr[l]), int(plan.bwd_ptr[l + 1])
            cs, ce = int(plan.bwd_col_ptr[l]), int(plan.bwd_col_ptr[l + 1])
            lcols = plan.bwd_level_cols[cs:ce]
            pu = _pow2(e - s)
            pc = _pow2(ce - cs)
            bwd_items.append((
                (
                    _pad_i32(lcols, pc, pad_row),
                    _pad_i32(diag[lcols], pc, pad_v),
                    _pad_i32(plan.bwd_rows[s:e], pu, pad_row),
                    _pad_i32(plan.bwd_cols[s:e], pu, pad_row),
                    _pad_i32(plan.bwd_vidx[s:e], pu, pad_v),
                ),
                (pc, pu),
            ))
        self._bwd_groups = build_groups(bwd_items)

    def solve(self, vals: jnp.ndarray, b) -> jnp.ndarray:
        # defensive copy: the jitted group steps donate the rhs buffer, and
        # ``jnp.asarray`` is a no-op on a JAX array already of vals.dtype —
        # without the copy the *caller's* array would be deleted
        x = jnp.array(b, dtype=vals.dtype, copy=True)
        for g in self._fwd_groups:
            x = _fwd_group(vals, x, *g)
        for g in self._bwd_groups:
            x = _bwd_group(vals, x, *g)
        return x

    def solve_batched(self, vals_batch: jnp.ndarray, b_batch) -> jnp.ndarray:
        """Row i of the result solves with factor values ``vals_batch[i]``
        and right-hand side ``b_batch[i]`` — B solves in lockstep."""
        vals = jnp.asarray(vals_batch)
        # defensive copy — same donation hazard as :meth:`solve`
        x = jnp.array(b_batch, dtype=vals.dtype, copy=True)
        if vals.ndim != 2 or x.ndim != 2 or vals.shape[0] != x.shape[0]:
            raise ValueError(
                f"expected (B, nnz) values and (B, n) rhs, got "
                f"{vals.shape} and {x.shape}")
        for g in self._fwd_groups:
            x = _fwd_group_batched(vals, x, *g)
        for g in self._bwd_groups:
            x = _bwd_group_batched(vals, x, *g)
        return x

    # -- iterative refinement -------------------------------------------------
    def solve_refined(self, vals, b, a_rows, a_cols, a_vals, a_abs,
                      max_iter: int, tol: float):
        """Solve then refine: up to ``max_iter`` sweeps of
        ``x += solve(b - A x)`` on the existing factors, stopping when the
        componentwise backward error drops to ``tol``.  ``a_rows``/
        ``a_cols``/``a_vals`` describe A (the matrix the factors came
        from) in COO entry order; ``a_abs`` is ``|a_vals|``.  Returns
        ``(x, info)`` with ``refine_iters``, ``backward_error``,
        ``converged``."""
        n = self.plan.n
        b = jnp.asarray(b, dtype=vals.dtype)
        x = self.solve(vals, b)             # solve makes its own rhs copy
        iters = 0
        r, berr = _residual_berr(a_rows, a_cols, a_vals, a_abs, x, b, n=n)
        while float(berr) > tol and iters < max_iter:
            x = x + self.solve(vals, r)
            iters += 1
            r, berr = _residual_berr(a_rows, a_cols, a_vals, a_abs, x, b, n=n)
        berr_f = float(berr)
        return x, {"refine_iters": iters, "backward_error": berr_f,
                   "converged": berr_f <= tol}

    def solve_refined_batched(self, vals, b, a_rows, a_cols, a_vals, a_abs,
                              max_iter: int, tol: float):
        """Batched twin of :meth:`solve_refined`: one lockstep sweep per
        round, corrections masked onto the still-unconverged rows, until
        every matrix meets ``tol`` or ``max_iter`` is reached.  Info fields
        are (B,) arrays."""
        n = self.plan.n
        b = jnp.asarray(b, dtype=vals.dtype)
        x = self.solve_batched(vals, b)     # solve makes its own rhs copy
        B = x.shape[0]
        iters = np.zeros(B, dtype=np.int64)
        r, berr = _residual_berr_batched(a_rows, a_cols, a_vals, a_abs, x, b,
                                         n=n)
        rounds = 0
        while bool((berr > tol).any()) and rounds < max_iter:
            active = np.asarray(berr) > tol
            d = self.solve_batched(vals, r)
            x = jnp.where(jnp.asarray(active)[:, None], x + d, x)
            iters[active] += 1
            rounds += 1
            r, berr = _residual_berr_batched(a_rows, a_cols, a_vals, a_abs,
                                             x, b, n=n)
        berr_np = np.asarray(berr)
        return x, {"refine_iters": iters, "backward_error": berr_np,
                   "converged": berr_np <= tol}
