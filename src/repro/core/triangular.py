"""Level-scheduled sparse triangular solves (Ly = b, Ux = y) in JAX,
plus batched iterative refinement on the device factors.

The forward sweep reuses the factorization levels (its dependency rule —
column j must wait for all c < j with L(j,c) != 0 — is exactly the paper's
"look left" relaxed rule, so the same levelization is valid).  The backward
sweep uses U-row levels computed at plan time.

Sparse right-hand sides: circuit RHS vectors are mostly zeros (an AC
excitation is often 1-2 entries), and the solution of ``L y = b`` is
supported exactly on the reach of ``nonzeros(b)`` in L's DAG (Gilbert-
Peierls; cf. Ruipeng Li, arXiv 1710.04985).  ``solve(..., rhs_pattern=...)``
prunes the level-group schedule to that reach — entries whose source column
is outside the closure contribute exact zeros and are dropped wholesale, so
the pruned solve is bit-identical to the full one on the reach.  Pruned
schedules are cached per rhs pattern (the contract is many solves per
pattern: a fixed excitation across a sweep).

Refinement runs on whatever system the factors describe (for the GLU facade
that is the scaled + permuted one): each sweep computes ``r = b - A x`` with
a sparse SpMV of A's values, the componentwise backward error
``max_i |r_i| / (|A||x| + |b|)_i`` as the stopping test, and — while above
tolerance — one more triangular solve on the existing factors.  Sweeps are
issued in chunks of ``sync_every`` with the convergence mask applied on
device, so the common ``refine <= 2`` case costs exactly ONE device->host
sync instead of one per sweep (``host_syncs`` in the returned info counts
them).
"""
from __future__ import annotations

from collections import OrderedDict
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..kernels.ops import masked_correction, spmv
from ..sparse.layout import pack_planes, pdiv, pmul, unpack_planes
from .executor import resolve_executable_cache
from .plan import FactorizePlan, bucketize, choose_buckets, pow2_pad

__all__ = ["JaxTriangularSolver", "trisolve_numpy"]


def trisolve_numpy(plan: FactorizePlan, vals: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Sequential oracle: unit-lower forward then upper backward solve."""
    n, indptr, indices = plan.n, plan.indptr, plan.indices
    vals = np.asarray(vals)
    dtype = np.result_type(vals.dtype, np.asarray(b).dtype, np.float64)
    vals = vals.astype(dtype, copy=False)
    x = np.array(b, dtype=dtype, copy=True)
    for j in range(n):
        e = int(indptr[j + 1])
        dp = int(plan.diag_idx[j])
        rows = indices[dp + 1 : e]
        x[rows] -= vals[dp + 1 : e] * x[j]
    for j in range(n - 1, -1, -1):
        s = int(indptr[j])
        dp = int(plan.diag_idx[j])
        x[j] /= vals[dp]
        rows = indices[s:dp]
        x[rows] -= vals[s:dp] * x[j]
    return x


def _pad_i32(x: np.ndarray, size: int, fill: int) -> np.ndarray:
    out = np.full(size, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


_pow2 = pow2_pad


def _fwd_group_body(vals, b, rows, cols, vidx):
    def body(bb, xs):
        r, c, v = xs
        lv = vals.at[v].get(mode="fill", fill_value=0.0)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-lv * xc, mode="drop"), None

    b, _ = jax.lax.scan(body, b, (rows, cols, vidx))
    return b


def _bwd_group_body(vals, b, lcols, ldiag, rows, cols, vidx):
    def body(bb, xs):
        lc, ld, r, c, v = xs
        dv = vals.at[ld].get(mode="fill", fill_value=1.0)
        xj = bb.at[lc].get(mode="fill", fill_value=0.0) / dv
        bb = bb.at[lc].set(xj, mode="drop")
        uv = vals.at[v].get(mode="fill", fill_value=0.0)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-uv * xc, mode="drop"), None

    b, _ = jax.lax.scan(body, b, (lcols, ldiag, rows, cols, vidx))
    return b


def _residual_berr_body(rows, cols, a_vals, a_abs, x, b, n):
    """r = b - A x and the componentwise backward error in one dispatch.
    Zero denominators (a row with |A||x| + |b| == 0) count as converged
    when the residual there is zero and as inf otherwise."""
    r = b - spmv(rows, cols, a_vals, x, n_rows=n)
    denom = spmv(rows, cols, a_abs, jnp.abs(x), n_rows=n) + jnp.abs(b)
    berr = jnp.max(jnp.where(denom > 0, jnp.abs(r) / denom,
                             jnp.where(jnp.abs(r) > 0, jnp.inf, 0.0)))
    return r, berr


@partial(jax.jit, static_argnames=("n",))
def _residual_berr(rows, cols, a_vals, a_abs, x, b, *, n):
    return _residual_berr_body(rows, cols, a_vals, a_abs, x, b, n)


@partial(jax.jit, static_argnames=("n",))
def _residual_berr_batched(rows, cols, a_vals, a_abs, x, b, *, n):
    return jax.vmap(
        lambda av, aa, xx, bb: _residual_berr_body(rows, cols, av, aa, xx, bb, n)
    )(a_vals, a_abs, x, b)


# Many-RHS twin: one value vector, (K, n) right-hand sides.
@partial(jax.jit, static_argnames=("n",))
def _residual_berr_multi(rows, cols, a_vals, a_abs, x, b, *, n):
    return jax.vmap(
        lambda xx, bb: _residual_berr_body(rows, cols, a_vals, a_abs, xx, bb, n)
    )(x, b)


# Planar twins: ``vals`` is (nnz, 2) split re/im planes and the running
# solution carries (n, 2) planes — the complex MAC / divide run on real
# operands (pmul/pdiv).  Index gathers are layout-agnostic (they gather
# plane ROWS), so the level-group schedule is shared with the native path.
def _fwd_group_planar_body(vals, b, rows, cols, vidx):
    def body(bb, xs):
        r, c, v = xs
        lv = vals.at[v].get(mode="fill", fill_value=0.0)     # (P, 2)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-pmul(lv, xc), mode="drop"), None

    b, _ = jax.lax.scan(body, b, (rows, cols, vidx))
    return b


def _bwd_group_planar_body(vals, b, lcols, ldiag, rows, cols, vidx):
    def body(bb, xs):
        lc, ld, r, c, v = xs
        # padded ldiag slots read (1, 1) planes; the pdiv result there is
        # discarded by the dropped set, same as the native fill_value=1.0
        dv = vals.at[ld].get(mode="fill", fill_value=1.0)
        xj = pdiv(bb.at[lc].get(mode="fill", fill_value=0.0), dv)
        bb = bb.at[lc].set(xj, mode="drop")
        uv = vals.at[v].get(mode="fill", fill_value=0.0)
        xc = bb.at[c].get(mode="fill", fill_value=0.0)
        return bb.at[r].add(-pmul(uv, xc), mode="drop"), None

    b, _ = jax.lax.scan(body, b, (lcols, ldiag, rows, cols, vidx))
    return b


_fwd_group = partial(jax.jit, donate_argnums=(1,))(_fwd_group_body)
_bwd_group = partial(jax.jit, donate_argnums=(1,))(_bwd_group_body)
_fwd_group_planar = partial(jax.jit, donate_argnums=(1,))(_fwd_group_planar_body)
_bwd_group_planar = partial(jax.jit, donate_argnums=(1,))(_bwd_group_planar_body)

# Batched twins: vals (B, nnz) and b (B, n) share the level-group index
# arrays, so each group stays ONE dispatch for the whole batch.
_fwd_group_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_fwd_group_body, in_axes=(0, 0, None, None, None)))
_bwd_group_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_bwd_group_body, in_axes=(0, 0, None, None, None, None, None)))

# Many-RHS twins: ONE factor value vector shared by every rhs row — the
# adjoint/sensitivity workload (K seeds against one factorization).
_fwd_group_multi = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_fwd_group_body, in_axes=(None, 0, None, None, None)))
_bwd_group_multi = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_bwd_group_body, in_axes=(None, 0, None, None, None, None, None)))

_fwd_group_planar_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_fwd_group_planar_body, in_axes=(0, 0, None, None, None)))
_bwd_group_planar_batched = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_bwd_group_planar_body,
             in_axes=(0, 0, None, None, None, None, None)))
_fwd_group_planar_multi = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_fwd_group_planar_body, in_axes=(None, 0, None, None, None)))
_bwd_group_planar_multi = partial(jax.jit, donate_argnums=(1,))(
    jax.vmap(_bwd_group_planar_body,
             in_axes=(None, 0, None, None, None, None, None)))


# -- whole-schedule fused trisolve -----------------------------------------
#
# One jitted program runs the forward sweep, the backward sweep, and the
# dtype cast of the rhs — a triangular solve is a single device dispatch
# instead of one per level group.  Neither ``vals`` (caller retains the
# factors) nor ``b`` (caller's rhs) is donated, which also removes the
# defensive rhs copy the per-group path needs.

def _solve_schedule_body(vals, b, fwd, bwd):
    x = jnp.asarray(b, dtype=vals.dtype)
    for g in fwd:
        x = _fwd_group_body(vals, x, *g)
    for g in bwd:
        x = _bwd_group_body(vals, x, *g)
    return x


def _solve_schedule_planar_body(vals, b, fwd, bwd):
    # planes in, native complex out: the rhs is packed INSIDE the fused
    # program and the solution unpacked at the end, so a planar triangular
    # solve still presents the complex interface in ONE dispatch
    x = pack_planes(b, vals.dtype)
    for g in fwd:
        x = _fwd_group_planar_body(vals, x, *g)
    for g in bwd:
        x = _bwd_group_planar_body(vals, x, *g)
    return unpack_planes(x)


def _build_trisolve_runner(kind: str, planar: bool = False, shard=None):
    body = _solve_schedule_planar_body if planar else _solve_schedule_body
    if kind == "single":
        fn = body
    elif kind == "batched":
        fn = jax.vmap(body, in_axes=(0, 0, None, None))
    else:  # "multi"
        fn = jax.vmap(body, in_axes=(None, 0, None, None))
    if shard is not None:
        if kind != "batched":
            raise ValueError("scenario sharding requires the batched kind")
        # value and rhs batches split along the scenario axes, the level
        # schedule is replicated; each shard's trisolve stays one dispatch.
        # Rows never interact, so the result is bit-identical to unsharded.
        bspec = shard.spec
        fn = shard_map(fn, mesh=shard.mesh, in_specs=(bspec, bspec, P(), P()),
                       out_specs=bspec, check_rep=False)
    return jax.jit(fn)


class JaxTriangularSolver:
    """solve(vals, b): forward+backward substitution on the factored values."""

    # pruned schedules kept per rhs pattern; enough for a handful of distinct
    # excitation/seed patterns without growing unboundedly under adversarial use
    SPARSE_SCHEDULE_CAP = 32

    def __init__(self, plan: FactorizePlan, fuse: bool = True,
                 fuse_buckets: bool = True, bucket_waste: float = 4.0,
                 jit_schedule: bool = True, executable_cache="default",
                 layout: str = "native", shard=None):
        if layout not in ("native", "planar"):
            raise ValueError(
                f"layout must be 'native' or 'planar', got {layout!r} "
                "(the solver has no dtype to resolve 'auto' against)")
        self.plan = plan
        # scenario sharding for batched solves (see JaxFactorizer): single
        # and multi-RHS kinds, and batches not divisible by the shard
        # count, fall back to the unsharded runner
        self.shard = shard if (shard is not None and shard.n_shards > 1) \
            else None
        # planar: factor values arrive as (nnz, 2) / (B, nnz, 2) split re/im
        # planes; rhs and solution stay native complex at the interface
        self.layout = layout
        self._planar = layout == "planar"
        self._fuse = fuse
        self._fuse_buckets = fuse_buckets and fuse
        self._bucket_waste = bucket_waste
        self.jit_schedule = jit_schedule
        self._exec_cache = resolve_executable_cache(executable_cache)
        # dispatch count of the most recent solve* call (1 on the fused
        # path; one per level group plus the rhs copy otherwise)
        self.last_n_dispatches = 0
        self._full_schedule = self._build_schedule(None, None)
        if self.shard is not None:
            # schedule index arrays are replicated once so the sharded
            # runner never re-lays them out per call
            self._full_schedule = self.shard.replicate(self._full_schedule)
        self._sparse_schedules: OrderedDict = OrderedDict()

    def _build_schedule(self, fwd_mask, bwd_mask):
        """Level-group schedule as (fwd_groups, bwd_groups).  ``fwd_mask`` /
        ``bwd_mask`` (boolean (n,) column masks) restrict the schedule to
        the masked columns; levels left empty are dropped entirely (fewer
        scheduled steps is where the sparse-RHS win comes from).

        With ``fuse_buckets`` the per-level pow2 pads are quantized up to a
        geometric ladder built from THIS schedule's level-size histogram, so
        runs of near-equal levels share one scan shape (the pad indices are
        inert, making over-padding bit-safe)."""
        plan, fuse = self.plan, self._fuse
        n = plan.n
        pad_row = n  # out-of-range -> drop
        pad_v = plan.nnz

        def make_pad(sizes_list):
            """size -> padded size, via the bucket ladder of this schedule."""
            if not self._fuse_buckets:
                return _pow2
            ladder = choose_buckets(np.asarray(sizes_list, dtype=np.int64),
                                    max_waste=self._bucket_waste)
            return lambda x: bucketize(_pow2(x), ladder)

        def build_groups(items):
            groups, run, run_shape = [], [], None

            def flush():
                nonlocal run, run_shape
                if run:
                    groups.append(
                        tuple(jnp.asarray(np.stack([r[i] for r in run]))
                              for i in range(len(run[0])))
                    )
                run, run_shape = [], None

            for arrs, shape in items:
                if fuse and shape == run_shape:
                    run.append(arrs)
                else:
                    flush()
                    run, run_shape = [arrs], shape
            flush()
            return groups

        fwd_raw = []
        nlev = len(plan.fwd_ptr) - 1
        for l in range(nlev):
            s, e = int(plan.fwd_ptr[l]), int(plan.fwd_ptr[l + 1])
            rows = plan.fwd_rows[s:e]
            cols = plan.fwd_cols[s:e]
            vidx = plan.fwd_vidx[s:e]
            if fwd_mask is not None:
                keep = fwd_mask[cols]
                if not keep.any():
                    continue
                rows, cols, vidx = rows[keep], cols[keep], vidx[keep]
            fwd_raw.append((rows, cols, vidx))
        fpad = make_pad([len(r[0]) for r in fwd_raw] or [1])
        fwd_items = []
        for rows, cols, vidx in fwd_raw:
            p = fpad(len(rows))
            fwd_items.append((
                (
                    _pad_i32(rows, p, pad_row),
                    _pad_i32(cols, p, pad_row),
                    _pad_i32(vidx, p, pad_v),
                ),
                p,
            ))
        fwd_groups = build_groups(fwd_items)

        bwd_raw = []
        nulev = len(plan.bwd_ptr) - 1
        diag = plan.diag_idx
        for l in range(nulev):
            s, e = int(plan.bwd_ptr[l]), int(plan.bwd_ptr[l + 1])
            cs, ce = int(plan.bwd_col_ptr[l]), int(plan.bwd_col_ptr[l + 1])
            lcols = plan.bwd_level_cols[cs:ce]
            rows = plan.bwd_rows[s:e]
            cols = plan.bwd_cols[s:e]
            vidx = plan.bwd_vidx[s:e]
            if bwd_mask is not None:
                keepc = bwd_mask[lcols]
                keepu = bwd_mask[cols]
                if not keepc.any() and not keepu.any():
                    continue
                lcols = lcols[keepc]
                rows, cols, vidx = rows[keepu], cols[keepu], vidx[keepu]
            bwd_raw.append((lcols, rows, cols, vidx))
        cpad = make_pad([len(r[0]) for r in bwd_raw] or [1])
        upad = make_pad([len(r[1]) for r in bwd_raw] or [1])
        bwd_items = []
        for lcols, rows, cols, vidx in bwd_raw:
            pc = cpad(len(lcols))
            pu = upad(len(rows))
            bwd_items.append((
                (
                    _pad_i32(lcols, pc, pad_row),
                    _pad_i32(diag[lcols], pc, pad_v),
                    _pad_i32(rows, pu, pad_row),
                    _pad_i32(cols, pu, pad_row),
                    _pad_i32(vidx, pu, pad_v),
                ),
                (pc, pu),
            ))
        bwd_groups = build_groups(bwd_items)
        return fwd_groups, bwd_groups

    # -- sparse-RHS schedule cache -------------------------------------------
    @staticmethod
    def _normalize_pattern(rhs_pattern) -> np.ndarray:
        pat = np.unique(np.asarray(rhs_pattern, dtype=np.int64).ravel())
        return pat

    def schedule_for_pattern(self, rhs_pattern):
        """The pruned (fwd_groups, bwd_groups, fwd_reach, bwd_reach) for a
        rhs supported on ``rhs_pattern``; memoized per pattern (LRU)."""
        pat = self._normalize_pattern(rhs_pattern)
        key = pat.tobytes()
        hit = self._sparse_schedules.get(key)
        if hit is not None:
            self._sparse_schedules.move_to_end(key)
            return hit
        n = self.plan.n
        freach = self.plan.fwd_reach(pat)
        breach = self.plan.bwd_reach(freach)
        if len(freach) == n and len(breach) == n:
            # the reach closure is every column: a "pruned" schedule would be
            # a redundant twin of the full one (same work, its own compiled
            # executables).  Reuse the full schedule OBJECT so the jit /
            # executable caches hit instead of recompiling.
            entry = (self._full_schedule[0], self._full_schedule[1],
                     freach, breach)
        else:
            fmask = np.zeros(n, dtype=bool)
            fmask[freach] = True
            bmask = np.zeros(n, dtype=bool)
            bmask[breach] = True
            fwd_groups, bwd_groups = self._build_schedule(fmask, bmask)
            if self.shard is not None:
                fwd_groups, bwd_groups = self.shard.replicate(
                    (fwd_groups, bwd_groups))
            entry = (fwd_groups, bwd_groups, freach, breach)
        self._sparse_schedules[key] = entry
        while len(self._sparse_schedules) > self.SPARSE_SCHEDULE_CAP:
            self._sparse_schedules.popitem(last=False)
        return entry

    def _groups_for(self, rhs_pattern):
        """(fwd_groups, bwd_groups, schedule_id) for the rhs support; the
        id distinguishes pruned schedules in the executable-cache key."""
        if rhs_pattern is None:
            fwd, bwd = self._full_schedule
            return fwd, bwd, "full"
        fwd, bwd, _, _ = self.schedule_for_pattern(rhs_pattern)
        if fwd is self._full_schedule[0]:       # full-reach shortcut hit
            return fwd, bwd, "full"
        key = self._normalize_pattern(rhs_pattern).tobytes()
        return fwd, bwd, key.hex()

    def _run_fused(self, kind: str, vals, x, fwd, bwd, sid: str):
        shard = self.shard
        if shard is not None and (kind != "batched"
                                  or vals.shape[0] % shard.n_shards != 0):
            shard = None
        runner = self._exec_cache.get_or_build(
            ("trisolve", self.plan.digest, sid, kind,
             None if shard is None else shard.descriptor, self.layout),
            lambda: _build_trisolve_runner(kind, planar=self._planar,
                                           shard=shard))
        out = runner(vals, x, tuple(fwd), tuple(bwd))
        self.last_n_dispatches = 1
        return out

    def _iface_dtype(self, vals):
        """The dtype of rhs/solution at the caller interface: the value
        dtype natively, the matching complex dtype for planar planes."""
        if self._planar:
            return np.dtype(np.complex64 if vals.dtype == np.float32
                            else np.complex128)
        return vals.dtype

    # -- solves ---------------------------------------------------------------
    def solve(self, vals: jnp.ndarray, b, rhs_pattern=None) -> jnp.ndarray:
        """With ``rhs_pattern`` (indices of b's nonzero support) the level
        schedule is pruned to the reach closure of the pattern; ``b`` MUST
        be zero outside it (the facade validates this)."""
        fwd, bwd, sid = self._groups_for(rhs_pattern)
        if self.jit_schedule:
            return self._run_fused("single", jnp.asarray(vals),
                                   jnp.asarray(b), fwd, bwd, sid)
        if self._planar:
            # pack_planes always allocates, so the donated running buffer
            # never aliases the caller's rhs
            vals = jnp.asarray(vals)
            x = pack_planes(b, vals.dtype)
            for g in fwd:
                x = _fwd_group_planar(vals, x, *g)
            for g in bwd:
                x = _bwd_group_planar(vals, x, *g)
            self.last_n_dispatches = len(fwd) + len(bwd) + 2
            return unpack_planes(x)
        # defensive copy: the jitted group steps donate the rhs buffer, and
        # ``jnp.asarray`` is a no-op on a JAX array already of vals.dtype —
        # without the copy the *caller's* array would be deleted
        x = jnp.array(b, dtype=vals.dtype, copy=True)
        for g in fwd:
            x = _fwd_group(vals, x, *g)
        for g in bwd:
            x = _bwd_group(vals, x, *g)
        self.last_n_dispatches = len(fwd) + len(bwd) + 1
        return x

    def solve_batched(self, vals_batch: jnp.ndarray, b_batch,
                      rhs_pattern=None) -> jnp.ndarray:
        """Row i of the result solves with factor values ``vals_batch[i]``
        and right-hand side ``b_batch[i]`` — B solves in lockstep.  A
        ``rhs_pattern`` is shared by the whole batch (union support)."""
        vals = jnp.asarray(vals_batch)
        fwd, bwd, sid = self._groups_for(rhs_pattern)
        b = jnp.asarray(b_batch)
        want = 3 if self._planar else 2
        if vals.ndim != want or b.ndim != 2 or vals.shape[0] != b.shape[0]:
            shape = "(B, nnz, 2)" if self._planar else "(B, nnz)"
            raise ValueError(
                f"expected {shape} values and (B, n) rhs, got "
                f"{vals.shape} and {b.shape}")
        if self.jit_schedule:
            return self._run_fused("batched", vals, b, fwd, bwd, sid)
        if self._planar:
            x = pack_planes(b, vals.dtype)
            for g in fwd:
                x = _fwd_group_planar_batched(vals, x, *g)
            for g in bwd:
                x = _bwd_group_planar_batched(vals, x, *g)
            self.last_n_dispatches = len(fwd) + len(bwd) + 2
            return unpack_planes(x)
        # defensive copy — same donation hazard as :meth:`solve`
        x = jnp.array(b, dtype=vals.dtype, copy=True)
        for g in fwd:
            x = _fwd_group_batched(vals, x, *g)
        for g in bwd:
            x = _bwd_group_batched(vals, x, *g)
        self.last_n_dispatches = len(fwd) + len(bwd) + 1
        return x

    def solve_multi(self, vals: jnp.ndarray, b_multi,
                    rhs_pattern=None) -> jnp.ndarray:
        """Many right-hand sides against ONE set of factor values: ``vals``
        is (nnz,), ``b_multi`` is (K, n), each level group is one dispatch
        for all K rhs (the adjoint/sensitivity workload).  A ``rhs_pattern``
        is the union support of all rows."""
        vals = jnp.asarray(vals)
        fwd, bwd, sid = self._groups_for(rhs_pattern)
        b = jnp.asarray(b_multi)
        want = 2 if self._planar else 1
        if vals.ndim != want or b.ndim != 2:
            shape = "(nnz, 2)" if self._planar else "(nnz,)"
            raise ValueError(
                f"expected {shape} values and (K, n) rhs, got "
                f"{vals.shape} and {b.shape}")
        if self.jit_schedule:
            return self._run_fused("multi", vals, b, fwd, bwd, sid)
        if self._planar:
            x = pack_planes(b, vals.dtype)
            for g in fwd:
                x = _fwd_group_planar_multi(vals, x, *g)
            for g in bwd:
                x = _bwd_group_planar_multi(vals, x, *g)
            self.last_n_dispatches = len(fwd) + len(bwd) + 2
            return unpack_planes(x)
        x = jnp.array(b, dtype=vals.dtype, copy=True)
        for g in fwd:
            x = _fwd_group_multi(vals, x, *g)
        for g in bwd:
            x = _bwd_group_multi(vals, x, *g)
        self.last_n_dispatches = len(fwd) + len(bwd) + 1
        return x

    # -- iterative refinement -------------------------------------------------
    def _solve_refined_impl(self, kind, vals, b, a_rows, a_cols, a_vals,
                            a_abs, max_iter, tol, rhs_pattern, sync_every):
        """Shared chunked-refinement driver.  The initial solve may use the
        pruned sparse-RHS schedule; corrections solve against a dense
        residual, so they always run the full schedule.  Convergence is
        masked on DEVICE (``masked_correction``) and the backward error only
        crosses to the host once per ``sync_every`` sweeps — the common
        ``max_iter <= sync_every`` case pays exactly one transfer."""
        n = self.plan.n
        # planar factors still refine against the NATIVE complex system:
        # casting b to vals.dtype would truncate a complex rhs to the real
        # plane dtype, so the cast targets the interface dtype instead
        b = jnp.asarray(b, dtype=self._iface_dtype(vals))
        if kind == "single":
            solve = self.solve
            res_fn = _residual_berr
        elif kind == "batched":
            solve = self.solve_batched
            res_fn = _residual_berr_batched
        else:
            solve = self.solve_multi
            res_fn = _residual_berr_multi
        x = solve(vals, b, rhs_pattern=rhs_pattern)
        n_disp = self.last_n_dispatches + 1    # + the residual/berr pass
        r, berr = res_fn(a_rows, a_cols, a_vals, a_abs, x, b, n=n)
        iters = jnp.zeros(berr.shape, dtype=jnp.int32)
        syncs = 0
        done = 0
        berr_h = iters_h = None
        while done < max_iter:
            chunk = min(max(1, int(sync_every)), max_iter - done)
            for _ in range(chunk):
                d = solve(vals, r)
                n_disp += self.last_n_dispatches + 2   # mask + residual
                x = masked_correction(x, d, berr, tol)
                iters = iters + (berr > tol)
                r, berr = res_fn(a_rows, a_cols, a_vals, a_abs, x, b, n=n)
            done += chunk
            berr_h, iters_h = jax.device_get((berr, iters))
            syncs += 1
            if np.all(berr_h <= tol):
                break
        if berr_h is None:                      # max_iter == 0
            berr_h, iters_h = jax.device_get((berr, iters))
            syncs += 1
        self.last_n_dispatches = n_disp
        if kind == "single":
            berr_out = float(berr_h)
            info = {"refine_iters": int(iters_h),
                    "backward_error": berr_out,
                    "converged": berr_out <= tol,
                    "host_syncs": syncs}
        else:
            berr_out = np.asarray(berr_h)
            info = {"refine_iters": np.asarray(iters_h, dtype=np.int64),
                    "backward_error": berr_out,
                    "converged": berr_out <= tol,
                    "host_syncs": syncs}
        return x, info

    def solve_refined(self, vals, b, a_rows, a_cols, a_vals, a_abs,
                      max_iter: int, tol: float, rhs_pattern=None,
                      sync_every: int = 2):
        """Solve then refine: up to ``max_iter`` sweeps of
        ``x += solve(b - A x)`` on the existing factors, stopping when the
        componentwise backward error drops to ``tol``.  ``a_rows``/
        ``a_cols``/``a_vals`` describe A (the matrix the factors came
        from) in COO entry order; ``a_abs`` is ``|a_vals|``.  Returns
        ``(x, info)`` with ``refine_iters``, ``backward_error``,
        ``converged``, ``host_syncs``."""
        return self._solve_refined_impl(
            "single", vals, b, a_rows, a_cols, a_vals, a_abs,
            max_iter, tol, rhs_pattern, sync_every)

    def solve_refined_batched(self, vals, b, a_rows, a_cols, a_vals, a_abs,
                              max_iter: int, tol: float, rhs_pattern=None,
                              sync_every: int = 2):
        """Batched twin of :meth:`solve_refined`: one lockstep sweep per
        round, corrections masked onto the still-unconverged rows, until
        every matrix meets ``tol`` or ``max_iter`` is reached.  Info fields
        are (B,) arrays."""
        return self._solve_refined_impl(
            "batched", vals, b, a_rows, a_cols, a_vals, a_abs,
            max_iter, tol, rhs_pattern, sync_every)

    def solve_refined_multi(self, vals, b, a_rows, a_cols, a_vals, a_abs,
                            max_iter: int, tol: float, rhs_pattern=None,
                            sync_every: int = 2):
        """Many-RHS twin: (nnz,) values, (K, n) right-hand sides, shared
        factors; info fields are (K,) arrays."""
        return self._solve_refined_impl(
            "multi", vals, b, a_rows, a_cols, a_vals, a_abs,
            max_iter, tol, rhs_pattern, sync_every)
