"""Symbolic fill-in analysis.

Three engines:

* ``symbolic_fillin_gp`` — exact Gilbert-Peierls reach-based fill (the
  paper's symbolic routine, inherited from the left-looking method).  Per
  column j it DFS-reaches the already-factorized L columns; everything
  reached is in the filled pattern.  Cost O(flops); pure host python.

* ``symbolic_fillin_vectorized`` — the same exact fill, computed by
  frontier-batched numpy reach passes instead of a per-column python DFS.
  Columns are batched by their height in the elimination tree of the
  symmetrised pattern: Liu's structure-containment theorem (L(i,j) != 0
  implies i is an ancestor of j) plus the superset relation between exact
  LU fill and the symmetrised Cholesky fill guarantee that equal-height
  columns never reach through each other, so each batch's reaches expand
  together in bulk array passes.

* ``symbolic_fillin_etree`` — elimination-tree symbolic factorization of the
  *symmetrised* pattern.  Produces a superset of the true LU fill (any
  superset is numerically exact for no-pivot LU: entries outside the true
  pattern simply factor to values that would have been computed anyway).
  Near O(nnz(L)) host cost; the default for large matrices.

All return the filled pattern ``As`` as (indptr, indices) with rows sorted
ascending per column, plus a scatter map from the original ``A`` entries into
the filled value array.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csc import CSC, concat_ranges

__all__ = [
    "FilledPattern",
    "resolve_symbolic_method",
    "symbolic_fillin",
    "symbolic_fillin_gp",
    "symbolic_fillin_etree",
    "symbolic_fillin_vectorized",
]


@dataclasses.dataclass
class FilledPattern:
    n: int
    indptr: np.ndarray      # (n+1,) int32 filled CSC structure
    indices: np.ndarray     # (nnz,) int32
    a_scatter: np.ndarray   # (nnz_A,) int64: filled-value index of each A entry
    method: str

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def filled_csc(self, A: CSC) -> CSC:
        """Scatter A's values into the filled pattern (zeros elsewhere),
        preserving the (promoted) value dtype — complex stays complex."""
        data = np.asarray(A.data)
        vals = np.zeros(self.nnz, dtype=np.result_type(data.dtype, np.float64))
        vals[self.a_scatter] = data
        return CSC(self.n, self.indptr, self.indices, vals)


def _scatter_map(A: CSC, indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """For each entry of A, its flat index in the filled pattern.

    Column-major (col, row) keys of a CSC pattern with per-column sorted rows
    are globally sorted, so one flat ``searchsorted`` over all columns
    replaces the per-column loop.
    """
    n = A.n
    fkeys = (np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr)) * n
             + indices.astype(np.int64))
    akeys = (np.repeat(np.arange(n, dtype=np.int64), np.diff(A.indptr)) * n
             + A.indices.astype(np.int64))
    pos = np.searchsorted(fkeys, akeys)
    ok = pos < len(fkeys)
    ok[ok] = fkeys[pos[ok]] == akeys[ok]
    if not ok.all():
        raise AssertionError("filled pattern does not contain A pattern")
    return pos.astype(np.int64)


def _scatter_map_loop(A: CSC, indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """Reference per-column implementation of :func:`_scatter_map` (kept for
    the equivalence test)."""
    out = np.empty(A.nnz, dtype=np.int64)
    for j in range(A.n):
        s, e = int(A.indptr[j]), int(A.indptr[j + 1])
        fs, fe = int(indptr[j]), int(indptr[j + 1])
        pos = np.searchsorted(indices[fs:fe], A.indices[s:e])
        if np.any(indices[fs + pos] != A.indices[s:e]):
            raise AssertionError("filled pattern does not contain A pattern")
        out[s:e] = fs + pos
    return out


def symbolic_fillin_gp(A: CSC) -> FilledPattern:
    """Exact reach-based fill-in (Gilbert-Peierls symbolic step)."""
    n = A.n
    # adjacency of already-built L columns: Lrows[j] = rows > j in column j
    Lrows: list[np.ndarray] = [None] * n  # type: ignore[assignment]
    col_patterns: list[np.ndarray] = []
    visited = np.zeros(n, dtype=bool)
    indptr = np.zeros(n + 1, dtype=np.int64)
    for j in range(n):
        s, e = int(A.indptr[j]), int(A.indptr[j + 1])
        seeds = A.indices[s:e]
        touched = []
        stack = list(seeds)
        while stack:
            k = stack.pop()
            if visited[k]:
                continue
            visited[k] = True
            touched.append(k)
            if k < j:
                # expand through column k of L
                for i in Lrows[k]:
                    if not visited[i]:
                        stack.append(i)
        pattern = np.array(sorted(touched), dtype=np.int32)
        visited[touched] = False
        # diagonal must be present (zero-free diagonal assumed post-MC64)
        if pattern.searchsorted(j) >= len(pattern) or pattern[pattern.searchsorted(j)] != j:
            pattern = np.insert(pattern, pattern.searchsorted(j), j)
        col_patterns.append(pattern)
        Lrows[j] = pattern[pattern > j]
        indptr[j + 1] = indptr[j] + len(pattern)
    indices = np.concatenate(col_patterns).astype(np.int32)
    indptr = indptr.astype(np.int32)
    return FilledPattern(n, indptr, indices, _scatter_map(A, indptr, indices), "gp")


def _etree_row_structures(n: int, upper_rows: list[np.ndarray]):
    """Rows of L of the symmetrised pattern via the Liu elimination-tree scan.

    ``upper_rows[i]`` = sorted {j < i : S(i,j) != 0} of the symmetrised
    pattern.  Returns per-row L structures (lists of k < i with L(i,k) != 0).
    """
    parent = np.full(n, -1, dtype=np.int64)
    mark = np.full(n, -1, dtype=np.int64)
    rows: list[list[int]] = [[] for _ in range(n)]
    for i in range(n):
        mark[i] = i
        for j in upper_rows[i]:
            k = int(j)
            while mark[k] != i:
                if parent[k] == -1:
                    parent[k] = i
                mark[k] = i
                rows[i].append(k)
                k = int(parent[k])
    return rows


def symbolic_fillin_etree(A: CSC) -> FilledPattern:
    """Symmetrised elimination-tree fill (superset of exact LU fill)."""
    n = A.n
    # build symmetrised strictly-upper row structures
    r, c, _ = A.to_coo()
    lo = np.minimum(r, c)
    hi = np.maximum(r, c)
    off = lo != hi
    lo, hi = lo[off], hi[off]
    key = hi.astype(np.int64) * n + lo.astype(np.int64)
    key = np.unique(key)
    hi_u = (key // n).astype(np.int64)
    lo_u = (key % n).astype(np.int64)
    upper_rows: list[np.ndarray] = []
    starts = np.searchsorted(hi_u, np.arange(n + 1))
    for i in range(n):
        upper_rows.append(lo_u[starts[i] : starts[i + 1]])
    rows = _etree_row_structures(n, upper_rows)
    # L row structures -> symmetric filled pattern: (i,k) and (k,i) for k in rows[i]
    total = sum(len(x) for x in rows)
    li = np.empty(total, dtype=np.int64)
    lk = np.empty(total, dtype=np.int64)
    p = 0
    for i, lst in enumerate(rows):
        m = len(lst)
        li[p : p + m] = i
        lk[p : p + m] = lst
        p += m
    rr = np.concatenate([li, lk, np.arange(n)])
    cc = np.concatenate([lk, li, np.arange(n)])
    order = np.lexsort((rr, cc))
    rr, cc = rr[order], cc[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, cc + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = rr.astype(np.int32)
    return FilledPattern(n, indptr, indices, _scatter_map(A, indptr, indices), "etree")


def _etree_symmetrized(n: int, indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """Elimination tree of the symmetrised pattern (Liu's algorithm with
    path compression).  ``parent[j] > j`` for every non-root."""
    cols = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    rows = indices.astype(np.int64)
    lo = np.minimum(rows, cols)
    hi = np.maximum(rows, cols)
    off = lo != hi
    key = np.unique(hi[off] * n + lo[off])  # sorted => grouped by hi ascending
    hi_u = key // n
    lo_u = key % n
    parent = np.full(n, -1, dtype=np.int64)
    ancestor = np.full(n, -1, dtype=np.int64)
    for i, k in zip(hi_u.tolist(), lo_u.tolist()):
        r = k
        while True:
            a = ancestor[r]
            if a == i:
                break
            ancestor[r] = i
            if a == -1:
                parent[r] = i
                break
            r = a
    return parent


def _etree_heights(parent: np.ndarray) -> np.ndarray:
    """Height of each node (longest path to a leaf below it).  ``parent[j] > j``
    lets one ascending pass finalize every node before it propagates."""
    n = len(parent)
    height = np.zeros(n, dtype=np.int64)
    par = parent.tolist()
    hts = height.tolist()
    for j in range(n):
        p = par[j]
        if p >= 0 and hts[j] + 1 > hts[p]:
            hts[p] = hts[j] + 1
    return np.asarray(hts, dtype=np.int64)


def symbolic_fillin_vectorized(A: CSC) -> FilledPattern:
    """Exact Gilbert-Peierls fill via frontier-batched, etree-pruned numpy
    reach passes.

    Identical output to :func:`symbolic_fillin_gp` (same pattern, same
    per-column sorted rows, same scatter map, modulo ``method``): the reach
    closure is computed breadth-first in bulk instead of depth-first per
    column.  Batching is exact — a column's reach only ever expands through
    columns of strictly smaller etree height, so every column of one height
    batch resolves in the same group of passes.
    """
    n = A.n
    if n == 0:
        return FilledPattern(0, np.zeros(1, np.int32), np.empty(0, np.int32),
                             np.empty(0, np.int64), "vectorized")
    indptr = np.asarray(A.indptr, dtype=np.int64)
    indices = np.asarray(A.indices, dtype=np.int64)
    parent = _etree_symmetrized(n, indptr, A.indices)
    height = _etree_heights(parent)
    horder = np.argsort(height, kind="stable").astype(np.int64)
    hsorted = height[horder]
    nbatch = int(hsorted[-1]) + 1
    bptr = np.searchsorted(hsorted, np.arange(nbatch + 1))

    # growing store of completed filled-L column structures (rows > j)
    l_start = np.zeros(n, dtype=np.int64)
    l_end = np.zeros(n, dtype=np.int64)
    lbuf = np.empty(max(A.nnz, 16), dtype=np.int64)
    lused = 0
    out_rows_parts = []
    out_cols_parts = []

    # membership bitmap, one row per in-flight column; big batches are chunked
    # so the bitmap stays bounded, and it is reset via the touched indices so
    # each batch pays O(reach), not O(rows * n)
    chunk_cap = max(1, 32_000_000 // max(n, 1))
    max_rows = 0
    for b in range(nbatch):
        max_rows = max(max_rows, min(int(bptr[b + 1] - bptr[b]), chunk_cap))
    visited = np.zeros((max_rows, n), dtype=bool)
    slot = np.empty(n, dtype=np.int64)

    for b in range(nbatch):
        batch = np.sort(horder[bptr[b] : bptr[b + 1]])
        for c0 in range(0, batch.size, chunk_cap):
            bcols = batch[c0 : c0 + chunk_cap]
            nb = bcols.size
            slot[bcols] = np.arange(nb)
            seeds = indices[concat_ranges(indptr[bcols], indptr[bcols + 1])]
            seed_cols = np.repeat(bcols, (indptr[bcols + 1] - indptr[bcols]))
            visited[slot[seed_cols], seeds] = True
            visited[np.arange(nb), bcols] = True     # forced diagonal
            keep = seeds < seed_cols
            f_col, f_node = seed_cols[keep], seeds[keep]
            while f_node.size:
                cnt = l_end[f_node] - l_start[f_node]
                nz = cnt > 0
                f_node, f_col, cnt = f_node[nz], f_col[nz], cnt[nz]
                if f_node.size == 0:
                    break
                flat = concat_ranges(l_start[f_node], l_end[f_node])
                crow = lbuf[flat]
                ccol = np.repeat(f_col, cnt)
                isnew = ~visited[slot[ccol], crow]
                if not isnew.any():
                    break
                ncol, nrow = ccol[isnew], crow[isnew]
                visited[slot[ncol], nrow] = True
                f_col, f_node = np.divmod(np.unique(ncol * n + nrow), n)
                keep = f_node < f_col
                f_col, f_node = f_col[keep], f_node[keep]
            sl, rows_b = np.nonzero(visited[:nb])
            cols_b = bcols[sl]                       # column-major order
            visited[sl, rows_b] = False              # cheap reset for reuse
            out_rows_parts.append(rows_b.astype(np.int64))
            out_cols_parts.append(cols_b)
            # publish this chunk's L structures for later expansions
            lm = rows_b > cols_b
            lrows, lcols = rows_b[lm], cols_b[lm]
            need = lused + lrows.size
            if need > lbuf.size:
                lbuf = np.concatenate(
                    [lbuf, np.empty(max(lbuf.size, need - lbuf.size), np.int64)])
            lbuf[lused:need] = lrows
            l_start[bcols] = lused + np.searchsorted(lcols, bcols)
            l_end[bcols] = lused + np.searchsorted(lcols, bcols, side="right")
            lused = need

    all_cols = np.concatenate(out_cols_parts)
    all_rows = np.concatenate(out_rows_parts)
    order = np.argsort(all_cols * n + all_rows, kind="stable")
    out_indptr = np.concatenate(
        [[0], np.cumsum(np.bincount(all_cols, minlength=n))]
    ).astype(np.int32)
    out_indices = all_rows[order].astype(np.int32)
    return FilledPattern(n, out_indptr, out_indices,
                         _scatter_map(A, out_indptr, out_indices), "vectorized")


def resolve_symbolic_method(n: int, method: str = "auto") -> str:
    """Resolve ``"auto"`` to the concrete engine used for an n-column matrix
    (part of the plan-cache key contract: keys are stored under resolved
    engine names so ``"auto"`` and its resolution share one plan)."""
    if method == "auto":
        return "gp" if n <= 3000 else "etree"
    if method in ("gp", "etree", "vectorized"):
        return method
    raise ValueError(f"unknown symbolic method {method!r}")


def symbolic_fillin(A: CSC, method: str = "auto") -> FilledPattern:
    method = resolve_symbolic_method(A.n, method)
    if method == "gp":
        return symbolic_fillin_gp(A)
    if method == "etree":
        return symbolic_fillin_etree(A)
    return symbolic_fillin_vectorized(A)
