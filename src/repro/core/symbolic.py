"""Symbolic fill-in analysis.

Two engines, matching DESIGN.md:

* ``symbolic_fillin_gp`` — exact Gilbert-Peierls reach-based fill (the
  paper's symbolic routine, inherited from the left-looking method).  Per
  column j it DFS-reaches the already-factorized L columns; everything
  reached is in the filled pattern.  Cost O(flops); pure host python.

* ``symbolic_fillin_etree`` — elimination-tree symbolic factorization of the
  *symmetrised* pattern.  Produces a superset of the true LU fill (any
  superset is numerically exact for no-pivot LU: entries outside the true
  pattern simply factor to values that would have been computed anyway).
  Near O(nnz(L)) host cost; the default for large matrices.

Both return the filled pattern ``As`` as (indptr, indices) with rows sorted
ascending per column, plus a scatter map from the original ``A`` entries into
the filled value array.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csc import CSC

__all__ = ["FilledPattern", "symbolic_fillin", "symbolic_fillin_gp", "symbolic_fillin_etree"]


@dataclasses.dataclass
class FilledPattern:
    n: int
    indptr: np.ndarray      # (n+1,) int32 filled CSC structure
    indices: np.ndarray     # (nnz,) int32
    a_scatter: np.ndarray   # (nnz_A,) int64: filled-value index of each A entry
    method: str

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def filled_csc(self, A: CSC) -> CSC:
        """Scatter A's values into the filled pattern (zeros elsewhere)."""
        vals = np.zeros(self.nnz, dtype=np.float64)
        vals[self.a_scatter] = np.asarray(A.data, dtype=np.float64)
        return CSC(self.n, self.indptr, self.indices, vals)


def _scatter_map(A: CSC, indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """For each entry of A, its flat index in the filled pattern."""
    out = np.empty(A.nnz, dtype=np.int64)
    for j in range(A.n):
        s, e = int(A.indptr[j]), int(A.indptr[j + 1])
        fs, fe = int(indptr[j]), int(indptr[j + 1])
        pos = np.searchsorted(indices[fs:fe], A.indices[s:e])
        if np.any(indices[fs + pos] != A.indices[s:e]):
            raise AssertionError("filled pattern does not contain A pattern")
        out[s:e] = fs + pos
    return out


def symbolic_fillin_gp(A: CSC) -> FilledPattern:
    """Exact reach-based fill-in (Gilbert-Peierls symbolic step)."""
    n = A.n
    # adjacency of already-built L columns: Lrows[j] = rows > j in column j
    Lrows: list[np.ndarray] = [None] * n  # type: ignore[assignment]
    col_patterns: list[np.ndarray] = []
    visited = np.zeros(n, dtype=bool)
    indptr = np.zeros(n + 1, dtype=np.int64)
    for j in range(n):
        s, e = int(A.indptr[j]), int(A.indptr[j + 1])
        seeds = A.indices[s:e]
        touched = []
        stack = list(seeds)
        while stack:
            k = stack.pop()
            if visited[k]:
                continue
            visited[k] = True
            touched.append(k)
            if k < j:
                # expand through column k of L
                for i in Lrows[k]:
                    if not visited[i]:
                        stack.append(i)
        pattern = np.array(sorted(touched), dtype=np.int32)
        visited[touched] = False
        # diagonal must be present (zero-free diagonal assumed post-MC64)
        if pattern.searchsorted(j) >= len(pattern) or pattern[pattern.searchsorted(j)] != j:
            pattern = np.insert(pattern, pattern.searchsorted(j), j)
        col_patterns.append(pattern)
        Lrows[j] = pattern[pattern > j]
        indptr[j + 1] = indptr[j] + len(pattern)
    indices = np.concatenate(col_patterns).astype(np.int32)
    indptr = indptr.astype(np.int32)
    return FilledPattern(n, indptr, indices, _scatter_map(A, indptr, indices), "gp")


def _etree_row_structures(n: int, upper_rows: list[np.ndarray]):
    """Rows of L of the symmetrised pattern via the Liu elimination-tree scan.

    ``upper_rows[i]`` = sorted {j < i : S(i,j) != 0} of the symmetrised
    pattern.  Returns per-row L structures (lists of k < i with L(i,k) != 0).
    """
    parent = np.full(n, -1, dtype=np.int64)
    mark = np.full(n, -1, dtype=np.int64)
    rows: list[list[int]] = [[] for _ in range(n)]
    for i in range(n):
        mark[i] = i
        for j in upper_rows[i]:
            k = int(j)
            while mark[k] != i:
                if parent[k] == -1:
                    parent[k] = i
                mark[k] = i
                rows[i].append(k)
                k = int(parent[k])
    return rows


def symbolic_fillin_etree(A: CSC) -> FilledPattern:
    """Symmetrised elimination-tree fill (superset of exact LU fill)."""
    n = A.n
    # build symmetrised strictly-upper row structures
    r, c, _ = A.to_coo()
    lo = np.minimum(r, c)
    hi = np.maximum(r, c)
    off = lo != hi
    lo, hi = lo[off], hi[off]
    key = hi.astype(np.int64) * n + lo.astype(np.int64)
    key = np.unique(key)
    hi_u = (key // n).astype(np.int64)
    lo_u = (key % n).astype(np.int64)
    upper_rows: list[np.ndarray] = []
    starts = np.searchsorted(hi_u, np.arange(n + 1))
    for i in range(n):
        upper_rows.append(lo_u[starts[i] : starts[i + 1]])
    rows = _etree_row_structures(n, upper_rows)
    # L row structures -> symmetric filled pattern: (i,k) and (k,i) for k in rows[i]
    total = sum(len(x) for x in rows)
    li = np.empty(total, dtype=np.int64)
    lk = np.empty(total, dtype=np.int64)
    p = 0
    for i, lst in enumerate(rows):
        m = len(lst)
        li[p : p + m] = i
        lk[p : p + m] = lst
        p += m
    rr = np.concatenate([li, lk, np.arange(n)])
    cc = np.concatenate([lk, li, np.arange(n)])
    order = np.lexsort((rr, cc))
    rr, cc = rr[order], cc[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, cc + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = rr.astype(np.int32)
    return FilledPattern(n, indptr, indices, _scatter_map(A, indptr, indices), "etree")


def symbolic_fillin(A: CSC, method: str = "auto") -> FilledPattern:
    if method == "auto":
        method = "gp" if A.n <= 3000 else "etree"
    if method == "gp":
        return symbolic_fillin_gp(A)
    if method == "etree":
        return symbolic_fillin_etree(A)
    raise ValueError(f"unknown symbolic method {method!r}")
