"""Planner subsystem: the GLU preprocessing pipeline as a first-class,
cacheable artifact.

GLU3.0's headline result is making *preprocessing* cheap; this module makes
it cheap to *skip*.  The full host-side symbolic pipeline

  MC64 matching -> fill-reducing ordering -> symbolic fill ->
  dependency levelization -> FactorizePlan -> scaling metadata

is split into its value-dependent part (the MC64 matching and Dr/Dc
scalings, recomputed per matrix — see :func:`compute_scaling`) and its
pattern-dependent part (everything else, owned by :class:`SymbolicPlan` and
built by :func:`build_symbolic_plan`).  :func:`plan_factorization` glues the
two together through a content-addressed :class:`PlanCache`:

  key = hash(indptr, indices, row_perm, resolved ordering,
             resolved symbolic, panel_threshold)

so a Newton re-scaling rebuild, a parameter-sweep corner, or a repeated
benchmark construction with a byte-identical pattern (and an unchanged MC64
matching — the usual case for diagonally dominant circuit Jacobians, whose
cheap-pass matching is the identity) reuses the whole symbolic artifact and
performs zero symbolic fill / dependency work.  The cache is an in-memory
LRU with optional on-disk persistence for cross-process reuse.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import threading
import time
from collections import OrderedDict
from typing import Optional, Union

import numpy as np

from ..sparse.csc import CSC, pattern_digest
from .dependency import Levelization, levelize_relaxed
from .ordering import (
    fill_reducing_ordering,
    max_product_matching,
    resolve_ordering_method,
    zero_free_diagonal,
)
from .plan import FactorizePlan, build_plan
from .symbolic import FilledPattern, resolve_symbolic_method, symbolic_fillin

__all__ = [
    "MC64Scaling",
    "PlanCache",
    "PlanCacheStats",
    "SymbolicPlan",
    "build_symbolic_plan",
    "compute_scaling",
    "default_plan_cache",
    "plan_factorization",
    "plan_key",
    "set_default_plan_cache",
]

# bumped whenever SymbolicPlan's layout changes, so stale on-disk plans from
# an older build never deserialize into a newer consumer
PLAN_FORMAT_VERSION = 3    # v3: FactorizePlan grew the content digest field
                           # (executable-cache key); v2 added reach adjacency


# --------------------------------------------------------------------------
# value-dependent half: MC64 matching + scalings
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MC64Scaling:
    """Value-dependent preprocessing output: the MC64 row permutation (old
    row -> new row) and the Duff-Koster dual scalings."""

    row_perm: np.ndarray
    Dr: np.ndarray
    Dc: np.ndarray

    @property
    def identity_scaling(self) -> bool:
        return bool(np.all(self.Dr == 1.0) and np.all(self.Dc == 1.0))


def compute_scaling(A: CSC, mc64: Union[str, bool, None] = "scale") -> MC64Scaling:
    """``"scale"``/``True`` — full Duff-Koster max-product matching with
    Dr/Dc scalings; ``"structural"`` — zero-free diagonal only;
    ``"none"``/``False``/``None`` — identity."""
    if mc64 in (True, "scale"):
        row_perm, Dr, Dc = max_product_matching(A)
    elif mc64 == "structural":
        row_perm = zero_free_diagonal(A)
        Dr = Dc = np.ones(A.n)
    elif mc64 in (False, None, "none"):
        row_perm = np.arange(A.n, dtype=np.int64)
        Dr = Dc = np.ones(A.n)
    else:
        raise ValueError(f"unknown mc64 mode {mc64!r}")
    return MC64Scaling(np.asarray(row_perm, dtype=np.int64), Dr, Dc)


# --------------------------------------------------------------------------
# pattern-dependent half: the SymbolicPlan artifact
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SymbolicPlan:
    """Everything the numeric phase needs that depends only on the sparsity
    pattern (plus the MC64 row permutation it was built under).

    Immutable by convention: one plan is shared by every ``GLU`` built from
    it, across re-scaling rebuilds, sweep corners and cache hits.
    """

    n: int
    key: str                      # content address (plan_key output)
    ordering: str                 # resolved method names
    symbolic: str
    panel_threshold: int
    # the original pattern the plan was built for (validation + scatter)
    orig_indptr: np.ndarray
    orig_indices: np.ndarray
    row_perm: np.ndarray          # MC64 matching the plan assumes
    row_map: np.ndarray           # old row -> new row (matching + ordering)
    col_map: np.ndarray           # old col -> new col
    inv_row: np.ndarray
    # permuted (pre-fill) pattern and the entry-order map into it
    perm_indptr: np.ndarray
    perm_indices: np.ndarray
    data_perm: np.ndarray         # original entry order -> permuted entry order
    pattern: FilledPattern        # filled pattern of the permuted matrix
    levelization: Levelization
    fplan: FactorizePlan
    spmv_rows: np.ndarray         # permuted-A COO layout for refinement SpMV
    spmv_cols: np.ndarray
    build_seconds: dict           # per-stage wall time of the build

    @property
    def nnz(self) -> int:
        return int(self.orig_indptr[-1])

    @property
    def nnz_filled(self) -> int:
        return self.pattern.nnz

    @property
    def num_levels(self) -> int:
        return self.levelization.num_levels

    def matches_pattern(self, A: CSC) -> bool:
        return (A.n == self.n
                and np.array_equal(np.asarray(A.indptr, dtype=np.int64),
                                   self.orig_indptr)
                and np.array_equal(np.asarray(A.indices, dtype=np.int64),
                                   self.orig_indices))

    def verify(self, **kwargs):
        """Run the static plan sanitizer (:func:`repro.analysis.verify_plan`)
        on this plan and return the :class:`~repro.analysis.VerifyReport`.
        Keyword arguments (``reach_trials``, ``seed``, ...) pass through."""
        from ..analysis import verify_plan   # lazy: analysis imports core

        return verify_plan(self, **kwargs)


def plan_key(
    n: int,
    indptr: np.ndarray,
    indices: np.ndarray,
    row_perm: np.ndarray,
    ordering: str = "auto",
    symbolic: str = "auto",
    panel_threshold: int = 16,
) -> str:
    """Content address of a symbolic plan.  ``"auto"`` methods are resolved
    first, so an explicit method and its auto-resolution share one entry."""
    return pattern_digest(
        np.asarray(indptr, dtype=np.int64),
        np.asarray(indices, dtype=np.int64),
        np.asarray(row_perm, dtype=np.int64),
        resolve_ordering_method(n, ordering),
        resolve_symbolic_method(n, symbolic),
        int(panel_threshold),
        PLAN_FORMAT_VERSION,
    )


def build_symbolic_plan(
    n: int,
    indptr: np.ndarray,
    indices: np.ndarray,
    row_perm: np.ndarray,
    ordering: str = "auto",
    symbolic: str = "auto",
    panel_threshold: int = 16,
    key: Optional[str] = None,
) -> SymbolicPlan:
    """Run the pattern-dependent preprocessing pipeline once."""
    t_total = time.perf_counter()
    ordering = resolve_ordering_method(n, ordering)
    symbolic = resolve_symbolic_method(n, symbolic)
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    row_perm = np.asarray(row_perm, dtype=np.int64)
    if key is None:
        key = plan_key(n, indptr, indices, row_perm, ordering, symbolic,
                       panel_threshold)
    rows0 = indices
    cols0 = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))

    t0 = time.perf_counter()
    # fill-reducing ordering runs on the row-permuted pattern (values are
    # irrelevant to mindeg/rcm, so a pattern-only CSC suffices)
    A_rp = CSC(n, indptr.astype(np.int32), indices.astype(np.int32),
               np.ones(len(rows0))).permute(row_perm,
                                            np.arange(n, dtype=np.int64))
    sym_perm = fill_reducing_ordering(A_rp, ordering)
    row_map = sym_perm[row_perm]
    col_map = sym_perm
    inv_row = np.argsort(row_map)
    t_ordering = time.perf_counter() - t0

    t0 = time.perf_counter()
    # permuted pattern + original-entry-order -> permuted-entry-order map
    data_perm = np.lexsort((row_map[rows0], col_map[cols0]))
    perm_rows = row_map[rows0][data_perm]
    perm_cols = col_map[cols0][data_perm]
    perm_indptr = np.concatenate(
        [[0], np.cumsum(np.bincount(perm_cols, minlength=n))]).astype(np.int32)
    perm_indices = perm_rows.astype(np.int32)
    A_perm = CSC(n, perm_indptr, perm_indices, np.ones(len(perm_rows)))
    t_permute = time.perf_counter() - t0

    t0 = time.perf_counter()
    pattern = symbolic_fillin(A_perm, symbolic)
    t_symbolic = time.perf_counter() - t0

    t0 = time.perf_counter()
    levelization = levelize_relaxed(pattern)
    t_levelize = time.perf_counter() - t0

    t0 = time.perf_counter()
    fplan = build_plan(pattern, levelization, panel_threshold=panel_threshold)
    t_plan = time.perf_counter() - t0

    return SymbolicPlan(
        n=n,
        key=key,
        ordering=ordering,
        symbolic=symbolic,
        panel_threshold=int(panel_threshold),
        orig_indptr=indptr,
        orig_indices=indices,
        row_perm=row_perm,
        row_map=row_map,
        col_map=col_map,
        inv_row=inv_row,
        perm_indptr=perm_indptr,
        perm_indices=perm_indices,
        data_perm=data_perm,
        pattern=pattern,
        levelization=levelization,
        fplan=fplan,
        spmv_rows=perm_rows.astype(np.int32),
        spmv_cols=perm_cols.astype(np.int32),
        build_seconds={
            "ordering": t_ordering,
            "permute": t_permute,
            "symbolic": t_symbolic,
            "levelize": t_levelize,
            "plan": t_plan,
            "total": time.perf_counter() - t_total,
        },
    )


# --------------------------------------------------------------------------
# content-addressed plan cache
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    builds: int = 0       # symbolic builds performed on behalf of this cache
    disk_hits: int = 0    # hits served by deserializing a persisted plan

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """Content-addressed LRU of :class:`SymbolicPlan` artifacts.

    ``capacity`` bounds the in-memory entry count (plans for big matrices
    hold the full update-triple arrays, so the default stays small).  With a
    ``directory``, every stored plan is also pickled to
    ``<directory>/<key>.plan`` and an in-memory miss falls through to disk —
    a warm start for repeated benchmark / serving processes.  Evictions only
    drop the memory copy; persisted plans stay on disk.
    """

    def __init__(self, capacity: int = 8, directory: Optional[str] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.directory = directory
        if directory is not None:
            os.makedirs(directory, exist_ok=True)
        self._plans: OrderedDict[str, SymbolicPlan] = OrderedDict()
        self._lock = threading.Lock()
        self.stats = PlanCacheStats()

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.plan")

    def get(self, key: str) -> Optional[SymbolicPlan]:
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                self.stats.hits += 1
                return plan
            if self.directory is not None:
                path = self._path(key)
                if os.path.exists(path):
                    try:
                        with open(path, "rb") as f:
                            version, plan = pickle.load(f)
                    except Exception:
                        version, plan = None, None
                    if version == PLAN_FORMAT_VERSION and plan is not None:
                        self._insert(key, plan)
                        self.stats.hits += 1
                        self.stats.disk_hits += 1
                        return plan
            self.stats.misses += 1
            return None

    def put(self, key: str, plan: SymbolicPlan) -> None:
        with self._lock:
            self._insert(key, plan)
            if self.directory is not None:
                tmp = self._path(key) + ".tmp"
                with open(tmp, "wb") as f:
                    pickle.dump((PLAN_FORMAT_VERSION, plan), f,
                                protocol=pickle.HIGHEST_PROTOCOL)
                os.replace(tmp, self._path(key))

    def _insert(self, key: str, plan: SymbolicPlan) -> None:
        self._plans[key] = plan
        self._plans.move_to_end(key)
        while len(self._plans) > self.capacity:
            self._plans.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        """Drop all in-memory entries (persisted plans stay on disk)."""
        with self._lock:
            self._plans.clear()

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: str) -> bool:
        return key in self._plans


_default_cache = PlanCache()


def default_plan_cache() -> PlanCache:
    """The process-wide cache the ``GLU`` facade uses by default."""
    return _default_cache


def set_default_plan_cache(cache: PlanCache) -> PlanCache:
    """Swap the process-wide default cache; returns the previous one."""
    global _default_cache
    old = _default_cache
    _default_cache = cache
    return old


def _resolve_cache(cache) -> Optional[PlanCache]:
    if cache == "default":
        return _default_cache
    if cache is None or isinstance(cache, PlanCache):
        return cache
    raise TypeError(f"plan_cache must be a PlanCache, 'default' or None, "
                    f"got {cache!r}")


def plan_factorization(
    A: CSC,
    ordering: str = "auto",
    symbolic: str = "auto",
    mc64: Union[str, bool, None] = "scale",
    panel_threshold: int = 16,
    cache: Union[PlanCache, str, None] = "default",
):
    """Full preprocessing with plan reuse.

    Runs the value-dependent MC64 stage, then either fetches the matching
    pattern-level :class:`SymbolicPlan` from ``cache`` or builds and stores
    it.  Returns ``(plan, scaling, from_cache)``.
    """
    scaling = compute_scaling(A, mc64)
    key = plan_key(A.n, A.indptr, A.indices, scaling.row_perm,
                   ordering, symbolic, panel_threshold)
    c = _resolve_cache(cache)
    plan = c.get(key) if c is not None else None
    if plan is not None:
        return plan, scaling, True
    plan = build_symbolic_plan(A.n, A.indptr, A.indices, scaling.row_perm,
                               ordering=ordering, symbolic=symbolic,
                               panel_threshold=panel_threshold, key=key)
    if c is not None:
        c.stats.builds += 1
        c.put(key, plan)
    return plan, scaling, False
