# GLU3.0 core: symbolic analysis, relaxed dependency detection, levelization,
# level-scheduled numeric factorization and triangular solves.
from .api import GLU
from .dependency import (
    Levelization,
    dependencies_doubleu,
    dependencies_relaxed,
    dependencies_upattern,
    level_stats,
    levelize,
    levelize_relaxed,
)
from .factorize import (
    JaxFactorizer,
    factorize_numpy,
    factorize_numpy_fast,
    leftlooking_numpy,
    split_lu,
)
from .ordering import (
    fill_reducing_ordering,
    max_product_matching,
    minimum_degree,
    rcm,
    zero_free_diagonal,
)
from .plan import FactorizePlan, build_plan
from .symbolic import FilledPattern, symbolic_fillin, symbolic_fillin_etree, symbolic_fillin_gp
from .triangular import JaxTriangularSolver, trisolve_numpy

__all__ = [
    "GLU",
    "Levelization",
    "dependencies_doubleu",
    "dependencies_relaxed",
    "dependencies_upattern",
    "level_stats",
    "levelize",
    "levelize_relaxed",
    "JaxFactorizer",
    "factorize_numpy",
    "factorize_numpy_fast",
    "leftlooking_numpy",
    "split_lu",
    "fill_reducing_ordering",
    "max_product_matching",
    "minimum_degree",
    "rcm",
    "zero_free_diagonal",
    "FactorizePlan",
    "build_plan",
    "FilledPattern",
    "symbolic_fillin",
    "symbolic_fillin_etree",
    "symbolic_fillin_gp",
    "JaxTriangularSolver",
    "trisolve_numpy",
]
