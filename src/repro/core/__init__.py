# GLU3.0 core: symbolic analysis, relaxed dependency detection, levelization,
# level-scheduled numeric factorization and triangular solves.
from .api import GLU
from .dependency import (
    Levelization,
    dependencies_doubleu,
    dependencies_relaxed,
    dependencies_upattern,
    level_stats,
    levelize,
    levelize_relaxed,
    longest_path_levels,
)
from .factorize import (
    JaxFactorizer,
    factorize_numpy,
    factorize_numpy_fast,
    leftlooking_numpy,
    split_lu,
)
from .ordering import (
    fill_reducing_ordering,
    max_product_matching,
    minimum_degree,
    rcm,
    zero_free_diagonal,
)
from .plan import FactorizePlan, build_plan
from .planner import (
    MC64Scaling,
    PlanCache,
    PlanCacheStats,
    SymbolicPlan,
    build_symbolic_plan,
    compute_scaling,
    default_plan_cache,
    plan_factorization,
    plan_key,
    set_default_plan_cache,
)
from .symbolic import (
    FilledPattern,
    symbolic_fillin,
    symbolic_fillin_etree,
    symbolic_fillin_gp,
    symbolic_fillin_vectorized,
)
from .triangular import JaxTriangularSolver, trisolve_numpy

__all__ = [
    "GLU",
    "Levelization",
    "MC64Scaling",
    "PlanCache",
    "PlanCacheStats",
    "SymbolicPlan",
    "build_symbolic_plan",
    "compute_scaling",
    "default_plan_cache",
    "plan_factorization",
    "plan_key",
    "set_default_plan_cache",
    "dependencies_doubleu",
    "dependencies_relaxed",
    "dependencies_upattern",
    "level_stats",
    "levelize",
    "levelize_relaxed",
    "longest_path_levels",
    "JaxFactorizer",
    "factorize_numpy",
    "factorize_numpy_fast",
    "leftlooking_numpy",
    "split_lu",
    "fill_reducing_ordering",
    "max_product_matching",
    "minimum_degree",
    "rcm",
    "zero_free_diagonal",
    "FactorizePlan",
    "build_plan",
    "FilledPattern",
    "symbolic_fillin",
    "symbolic_fillin_etree",
    "symbolic_fillin_gp",
    "symbolic_fillin_vectorized",
    "JaxTriangularSolver",
    "trisolve_numpy",
]
