"""Column dependency detection + levelization (the paper's first contribution).

Three detectors over the filled pattern ``As``:

* ``dependencies_upattern`` — GLU1.0 rule: column k depends on i < k iff
  ``As(i,k) != 0`` and column i of L is non-empty.  Misses double-U hazards.
* ``dependencies_doubleu`` — GLU2.0's exact double-U detection (paper
  Alg. 3): the expensive triple-nested scan.  Returned edges are *only* the
  double-U edges; GLU2.0's full dependency set is upattern ∪ doubleu.
* ``dependencies_relaxed`` — GLU3.0 (paper Alg. 4): U-pattern rule plus the
  "look left" L-row rule — a sufficient superset found in two flat loops.

``levelize`` turns any edge set into levels (longest-path from sources);
``levelize_relaxed`` fuses detection+levelization the way the production
code path does (no edge materialisation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.csc import concat_ranges as _concat_ranges
from ..sparse.csc import csc_transpose_pattern
from .symbolic import FilledPattern

__all__ = [
    "Levelization",
    "dependencies_upattern",
    "dependencies_relaxed",
    "dependencies_doubleu",
    "dependencies_exact",
    "levelize",
    "levelize_relaxed",
    "level_stats",
    "longest_path_levels",
]


@dataclasses.dataclass
class Levelization:
    levels: np.ndarray        # (n,) int32 level of each column
    order: np.ndarray         # (n,) columns grouped by level
    level_ptr: np.ndarray     # (nlevels+1,) offsets into ``order``

    @property
    def num_levels(self) -> int:
        return len(self.level_ptr) - 1

    def columns_at(self, lv: int) -> np.ndarray:
        return self.order[self.level_ptr[lv] : self.level_ptr[lv + 1]]


def _l_nonempty(As: FilledPattern) -> np.ndarray:
    """Boolean per column: does column j have any L entry (row > j)?"""
    n = As.n
    last = As.indices[np.maximum(As.indptr[1:] - 1, As.indptr[:-1])]
    out = last > np.arange(n)
    # columns with zero entries (cannot happen post-fill, diag always present)
    empty = As.indptr[1:] == As.indptr[:-1]
    out[empty] = False
    return out


def dependencies_upattern(As: FilledPattern) -> tuple[np.ndarray, np.ndarray]:
    """GLU1.0 edges as (src, dst): dst depends on src."""
    n = As.n
    cols = np.repeat(np.arange(n, dtype=np.int32), np.diff(As.indptr))
    rows = As.indices
    lne = _l_nonempty(As)
    m = (rows < cols) & lne[rows]
    return rows[m].astype(np.int64), cols[m].astype(np.int64)


def dependencies_relaxed(As: FilledPattern) -> tuple[np.ndarray, np.ndarray]:
    """GLU3.0 (Alg. 4) edges as (src, dst) — vectorised two-rule scan."""
    n = As.n
    cols = np.repeat(np.arange(n, dtype=np.int32), np.diff(As.indptr))
    rows = As.indices
    lne = _l_nonempty(As)
    up = (rows < cols) & lne[rows]          # look up: U pattern
    left = rows > cols                      # look left: L row pattern
    src = np.concatenate([rows[up], cols[left]]).astype(np.int64)
    dst = np.concatenate([cols[up], rows[left]]).astype(np.int64)
    return src, dst


def dependencies_doubleu(As: FilledPattern) -> tuple[np.ndarray, np.ndarray]:
    """GLU2.0 (Alg. 3) exact double-U detection.  Deliberately faithful to the
    paper's triple-nested structure (this is the slow baseline being
    replaced); row patterns come from a CSR view, membership tests use
    sorted-array intersection."""
    n = As.n
    indptr_t, indices_t, _ = csc_transpose_pattern(n, As.indptr, As.indices)

    def row_pattern(i):
        return indices_t[indptr_t[i] : indptr_t[i + 1]]

    src, dst = [], []
    for i in range(n):
        Ii = row_pattern(i)
        s, e = int(As.indptr[i]), int(As.indptr[i + 1])
        col_i = As.indices[s:e]
        for t in col_i[col_i > i]:          # A_s(t, i) != 0, t > i
            ts, te = int(As.indptr[t]), int(As.indptr[t + 1])
            col_t = As.indices[ts:te]
            hit = False
            for j in col_t[col_t >= t]:     # A_s(j, t) != 0
                Ij = row_pattern(j)
                # exists k in Ii ∩ Ij with k > t ?
                ka = Ii[np.searchsorted(Ii, t + 1):]
                kb = Ij[np.searchsorted(Ij, t + 1):]
                if len(np.intersect1d(ka, kb, assume_unique=True)):
                    hit = True
                    break
            if hit:
                src.append(int(i))
                dst.append(int(t))
    return np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64)


def dependencies_exact(As: FilledPattern) -> tuple[np.ndarray, np.ndarray]:
    """Exact hazard set of the level-synchronous right-looking executor.

    Source column j — with L rows R(j) = {r > j : As(r,j) != 0} and U-row
    targets K(j) = {k > j : As(j,k) != 0} — writes the entries (r, k) for
    every (r, k) in R(j) x K(j).  The written entry belongs to column
    max(r, k) and is consumed at the level of column min(r, k): the
    normalisation of min(r,k) when r >= k, the update sourced at row r when
    r < k.  Deduplicating j -> min(r, k) over the cross product gives

        { j -> k : k in K(j), k <= max R(j) }  ∪
        { j -> r : r in R(j), r < max K(j) }

    — O(nnz) edges, a strict subset of the relaxed rule (which takes ALL of
    K(j) and R(j)); the j -> r edges with As(j, r) == 0 are exactly the
    double-U hazards GLU1.0 misses.  Any levelization is a valid schedule
    for the executor iff every one of these edges is strictly
    level-forward — which is what ``repro.analysis.verify_plan`` checks.
    """
    n = As.n
    indptr = As.indptr.astype(np.int64)
    rows = As.indices.astype(np.int64)
    cols = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    low = rows > cols                       # L entries (r, j)
    maxR = np.full(n, -1, dtype=np.int64)
    np.maximum.at(maxR, cols[low], rows[low])
    indptr_t, indices_t, _ = csc_transpose_pattern(n, As.indptr, As.indices)
    rws = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr_t))
    kcols = indices_t.astype(np.int64)
    upr = kcols > rws                       # U entries (j, k)
    maxK = np.full(n, -1, dtype=np.int64)
    np.maximum.at(maxK, rws[upr], kcols[upr])
    m1 = upr & (kcols <= maxR[rws])         # j -> k, consumed by norm of k
    m2 = low & (rows < maxK[cols])          # j -> r, consumed by source r
    src = np.concatenate([rws[m1], cols[m2]])
    dst = np.concatenate([kcols[m1], rows[m2]])
    return src, dst


def _levels_to_levelization(levels: np.ndarray) -> Levelization:
    nlev = int(levels.max()) + 1 if len(levels) else 0
    order = np.argsort(levels, kind="stable").astype(np.int32)
    counts = np.bincount(levels, minlength=nlev)
    level_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return Levelization(levels.astype(np.int32), order, level_ptr)


def longest_path_levels(n: int, src: np.ndarray, dst: np.ndarray,
                        round_cap: int = 128) -> np.ndarray:
    """Longest-path level of every node of a DAG whose edges all satisfy
    ``src < dst`` (duplicate edges allowed).

    Vectorised frontier sweep: each round finalizes every node whose
    in-edges are all resolved and pushes ``level+1`` along its out-edges, so
    each edge is touched exactly once — O(E) total plus a handful of numpy
    calls per round.  Chain-like graphs (critical path ~ n) would degenerate
    into n tiny rounds, so after ``round_cap`` rounds the unfinished
    remainder falls back to the sequential index-order sweep, which is valid
    because every source of a pending node has a smaller index.
    """
    levels = np.zeros(n, dtype=np.int64)
    if len(src) == 0 or n == 0:
        return levels
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    o = np.argsort(src, kind="stable")
    src_s, dst_s = src[o], dst[o]
    optr = np.searchsorted(src_s, np.arange(n + 1))
    pend = np.bincount(dst_s, minlength=n)   # unresolved in-edges, with multiplicity
    frontier = np.flatnonzero(pend == 0)
    rounds = 0
    while frontier.size and rounds < round_cap:
        cnt = optr[frontier + 1] - optr[frontier]
        f = frontier[cnt > 0]
        if f.size == 0:
            break
        e = _concat_ranges(optr[f], optr[f + 1])
        d = dst_s[e]
        np.maximum.at(levels, d, np.repeat(levels[f] + 1, (optr[f + 1] - optr[f])))
        np.subtract.at(pend, d, 1)
        frontier = np.unique(d[pend[d] == 0])
        rounds += 1
    remaining = np.flatnonzero(pend > 0)
    if remaining.size:
        o2 = np.argsort(dst_s, kind="stable")
        src_d, dst_d = src_s[o2], dst_s[o2]
        dptr = np.searchsorted(dst_d, np.arange(n + 1))
        for k in remaining.tolist():             # ascending: sources final first
            levels[k] = levels[src_d[dptr[k] : dptr[k + 1]]].max() + 1
    return levels


def levelize(n: int, src: np.ndarray, dst: np.ndarray) -> Levelization:
    """Longest-path levels from an explicit edge list (all edges src < dst)."""
    return _levels_to_levelization(longest_path_levels(n, src, dst))


def levelize_relaxed(As: FilledPattern) -> Levelization:
    """Fused Alg. 4 + levelization (production path)."""
    src, dst = dependencies_relaxed(As)
    return levelize(As.n, src, dst)


def level_stats(As: FilledPattern, lv: Levelization):
    """Per-level (n_columns, max_subcolumns, total_updates) — the Fig. 10 data.

    subcolumns of column j = nonzeros of row j right of the diagonal;
    updates of column j = nnz_L(j) * n_subcolumns(j).
    """
    n = As.n
    indptr_t, indices_t, _ = csc_transpose_pattern(n, As.indptr, As.indices)
    cols = np.repeat(np.arange(n, dtype=np.int32), np.diff(As.indptr))
    nnz_l = np.bincount(cols[As.indices > cols], minlength=n)
    rows_r = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr_t))
    nsub = np.bincount(rows_r[indices_t > rows_r], minlength=n)
    upd = nnz_l.astype(np.int64) * nsub.astype(np.int64)
    nlev = lv.num_levels
    out = np.zeros((nlev, 3), dtype=np.int64)
    for l in range(nlev):
        cs = lv.columns_at(l)
        out[l, 0] = len(cs)
        out[l, 1] = nsub[cs].max() if len(cs) else 0
        out[l, 2] = upd[cs].sum()
    return out
