"""Numeric LU factorization executors.

* ``factorize_numpy``      — paper Alg. 2 (hybrid right-looking), sequential
                             host oracle, verbatim loop structure.
* ``leftlooking_numpy``    — paper Alg. 1 (G/P left-looking) baseline.
* ``JaxFactorizer``        — the GLU3.0 executor: level-scheduled, three
                             adaptive modes, scan-fused small levels,
                             optional Pallas segmented kernel.

The JaxFactorizer is built once from a :class:`FactorizePlan` and reused for
every refactorization with new numeric values on the same pattern (the
Newton-Raphson inner loop of circuit simulation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..distributed.collectives import psum_exact
from ..sparse.layout import pabs, pack_planes, pdiv, pmul, resolve_layout
from .executor import resolve_executable_cache
from .plan import (
    MODE_FLAT,
    MODE_PANEL,
    MODE_SEGMENTED,
    FactorizePlan,
    bucketize,
    pow2_pad,
)
from .symbolic import FilledPattern

__all__ = ["factorize_numpy", "leftlooking_numpy", "JaxFactorizer", "split_lu"]


# --------------------------------------------------------------------------
# Host oracles (verbatim paper algorithms)
# --------------------------------------------------------------------------

def _oracle_dtype(vals) -> np.dtype:
    """Working dtype of the host oracles: the input's dtype promoted to at
    least 64-bit precision (float64 for real, complex128 for complex)."""
    return np.result_type(np.asarray(vals).dtype, np.float64)


def factorize_numpy(As: FilledPattern, vals: np.ndarray) -> np.ndarray:
    """Paper Algorithm 2: hybrid column right-looking LU (sequential oracle)."""
    n, indptr, indices = As.n, As.indptr, As.indices
    vals = np.array(vals, dtype=_oracle_dtype(vals), copy=True)
    for j in range(n):
        s, e = int(indptr[j]), int(indptr[j + 1])
        rows = indices[s:e]
        dp = s + int(np.searchsorted(rows, j))
        diag = vals[dp]
        # compute column j of L
        vals[dp + 1 : e] /= diag
        # update the submatrix: for k > j with As(j, k) != 0
        lrows = rows[dp + 1 - s :]
        lvals = vals[dp + 1 : e]
        if len(lrows) == 0:
            continue
        for k in range(j + 1, n):
            ks, ke = int(indptr[k]), int(indptr[k + 1])
            p = ks + int(np.searchsorted(indices[ks:ke], j))
            if p < ke and indices[p] == j:
                ujk = vals[p]
                pos = ks + np.searchsorted(indices[ks:ke], lrows)
                vals[pos] -= lvals * ujk
    return vals


def _row_major_view(As: FilledPattern):
    from ..sparse.csc import csc_transpose_pattern

    return csc_transpose_pattern(As.n, As.indptr, As.indices)


def factorize_numpy_fast(As: FilledPattern, vals: np.ndarray) -> np.ndarray:
    """Same math as :func:`factorize_numpy`, using a CSR view to find the
    subcolumns of j directly (used by larger tests/benchmarks)."""
    n, indptr, indices = As.n, As.indptr, As.indices
    indptr_t, indices_t, pos_t = _row_major_view(As)
    vals = np.array(vals, dtype=_oracle_dtype(vals), copy=True)
    for j in range(n):
        s, e = int(indptr[j]), int(indptr[j + 1])
        rows = indices[s:e]
        dp = s + int(np.searchsorted(rows, j))
        vals[dp + 1 : e] /= vals[dp]
        lrows = rows[dp + 1 - s :]
        lvals = vals[dp + 1 : e]
        if len(lrows) == 0:
            continue
        ts, te = int(indptr_t[j]), int(indptr_t[j + 1])
        krange = indices_t[ts:te]
        kpos = pos_t[ts:te]
        right = krange > j
        for k, up in zip(krange[right], kpos[right]):
            ks, ke = int(indptr[k]), int(indptr[k + 1])
            pos = ks + np.searchsorted(indices[ks:ke], lrows)
            vals[pos] -= lvals * vals[up]
    return vals


def leftlooking_numpy(As: FilledPattern, vals: np.ndarray) -> np.ndarray:
    """Paper Algorithm 1: Gilbert-Peierls left-looking LU (baseline)."""
    n, indptr, indices = As.n, As.indptr, As.indices
    vals = np.array(vals, dtype=_oracle_dtype(vals), copy=True)
    for j in range(n):
        s, e = int(indptr[j]), int(indptr[j + 1])
        rows = indices[s:e]
        dp = s + int(np.searchsorted(rows, j))
        # triangular solve: for k < j with As(k, j) != 0 ascending
        for p in range(s, dp):
            k = int(indices[p])
            akj = vals[p]
            ks, ke = int(indptr[k]), int(indptr[k + 1])
            kdp = ks + int(np.searchsorted(indices[ks:ke], k))
            lrows = indices[kdp + 1 : ke]
            if len(lrows) == 0:
                continue
            pos = s + np.searchsorted(rows, lrows)
            vals[pos] -= vals[kdp + 1 : ke] * akj
        vals[dp + 1 : e] /= vals[dp]
    return vals


def split_lu(As: FilledPattern, vals: np.ndarray):
    """Split factorized values into scipy L (unit diag) and U matrices."""
    import scipy.sparse as sp

    n, indptr, indices = As.n, As.indptr, As.indices
    vals = np.asarray(vals)
    cols = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    lower = indices > cols
    upper = ~lower
    L = sp.coo_matrix((vals[lower], (indices[lower], cols[lower])), shape=(n, n)).tocsc()
    L = L + sp.eye(n, format="csc")
    U = sp.coo_matrix((vals[upper], (indices[upper], cols[upper])), shape=(n, n)).tocsc()
    return L, U


# --------------------------------------------------------------------------
# JAX executor
# --------------------------------------------------------------------------

def _pad_to(x: np.ndarray, size: int, fill: int) -> np.ndarray:
    out = np.full(size, fill, dtype=np.int32)
    out[: len(x)] = x
    return out


_pow2 = pow2_pad


def _level_step_body(vals, norm_idx, norm_diag, lidx, uidx, didx):
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(lv / dv, mode="drop")
    l = vals.at[lidx].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx].get(mode="fill", fill_value=0.0)
    return vals.at[didx].add(-l * u, mode="drop")


def _scan_steps_body(vals, norm_idx, norm_diag, lidx, uidx, didx):
    """Run a stack of same-shape levels sequentially inside one dispatch."""

    def body(v, xs):
        return _level_step_body(v, *xs), None

    vals, _ = jax.lax.scan(body, vals, (norm_idx, norm_diag, lidx, uidx, didx))
    return vals


def _level_step_robust_body(vals, lev_diag, tau, norm_idx, norm_diag,
                            lidx, uidx, didx):
    """Level step with static pivot perturbation: diagonals of the level's
    columns are final once all earlier levels ran, so any ``|d| < tau`` is
    bumped right before the divisions that would otherwise produce
    inf/NaN (one bump rule for every executor path: _perturb_diags_body)."""
    from ..kernels.ops import _perturb_diags_body

    vals, n_bumped = _perturb_diags_body(vals, lev_diag, tau)
    return _level_step_body(vals, norm_idx, norm_diag, lidx, uidx, didx), n_bumped


def _scan_steps_robust_body(vals, lev_diag, tau, norm_idx, norm_diag,
                            lidx, uidx, didx):
    def body(v, xs):
        v, c = _level_step_robust_body(v, xs[0], tau, *xs[1:])
        return v, c

    vals, counts = jax.lax.scan(
        body, vals, (lev_diag, norm_idx, norm_diag, lidx, uidx, didx))
    return vals, jnp.sum(counts)


_level_step = partial(jax.jit, donate_argnums=(0,))(_level_step_body)
_scan_steps = partial(jax.jit, donate_argnums=(0,))(_scan_steps_body)
_level_step_robust = partial(jax.jit, donate_argnums=(0,))(_level_step_robust_body)
_scan_steps_robust = partial(jax.jit, donate_argnums=(0,))(_scan_steps_robust_body)

# Batched twins: vals carries a leading batch axis (B, nnz); the per-level
# index arrays are shared across the batch, so each group is still ONE
# device dispatch for the whole batch.  The un-jitted ``*_body`` vmaps are
# reused inside the whole-schedule fused program.
_IN_AXES = (0, None, None, None, None, None)
_level_step_batched_body = jax.vmap(_level_step_body, in_axes=_IN_AXES)
_scan_steps_batched_body = jax.vmap(_scan_steps_body, in_axes=_IN_AXES)
_level_step_batched = partial(jax.jit, donate_argnums=(0,))(
    _level_step_batched_body)
_scan_steps_batched = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_batched_body)
# robust twins additionally map the per-matrix perturbation threshold tau
_IN_AXES_ROBUST = (0, None, 0, None, None, None, None, None)
_level_step_robust_batched_body = jax.vmap(_level_step_robust_body,
                                           in_axes=_IN_AXES_ROBUST)
_scan_steps_robust_batched_body = jax.vmap(_scan_steps_robust_body,
                                           in_axes=_IN_AXES_ROBUST)
_level_step_robust_batched = partial(jax.jit, donate_argnums=(0,))(
    _level_step_robust_batched_body)
_scan_steps_robust_batched = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_robust_batched_body)


# Planar complex twins (layout="planar"): ``vals`` carries split re/im
# planes, (nnz, 2) single / (B, nnz, 2) batched.  All index machinery is
# identical — gathers/scatters on a (nnz, 2) array index ROWS, so the same
# plan arrays and pad-index-== nnz drop/fill semantics apply — only the
# value arithmetic changes: complex MAC = 4 real MACs + sign (``pmul``),
# normalisation divides by conj(d)/|d|^2 (``pdiv``).

def _level_step_planar_body(vals, norm_idx, norm_diag, lidx, uidx, didx):
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(pdiv(lv, dv), mode="drop")
    l = vals.at[lidx].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx].get(mode="fill", fill_value=0.0)
    return vals.at[didx].add(-pmul(l, u), mode="drop")


def _scan_steps_planar_body(vals, norm_idx, norm_diag, lidx, uidx, didx):
    def body(v, xs):
        return _level_step_planar_body(v, *xs), None

    vals, _ = jax.lax.scan(body, vals,
                           (norm_idx, norm_diag, lidx, uidx, didx))
    return vals


def _level_step_robust_planar_body(vals, lev_diag, tau, norm_idx, norm_diag,
                                   lidx, uidx, didx):
    from ..kernels.ops import _perturb_diags_planar_body

    vals, n_bumped = _perturb_diags_planar_body(vals, lev_diag, tau)
    return (_level_step_planar_body(vals, norm_idx, norm_diag,
                                    lidx, uidx, didx), n_bumped)


def _scan_steps_robust_planar_body(vals, lev_diag, tau, norm_idx, norm_diag,
                                   lidx, uidx, didx):
    def body(v, xs):
        v, c = _level_step_robust_planar_body(v, xs[0], tau, *xs[1:])
        return v, c

    vals, counts = jax.lax.scan(
        body, vals, (lev_diag, norm_idx, norm_diag, lidx, uidx, didx))
    return vals, jnp.sum(counts)


_level_step_planar = partial(jax.jit, donate_argnums=(0,))(
    _level_step_planar_body)
_scan_steps_planar = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_planar_body)
_level_step_robust_planar = partial(jax.jit, donate_argnums=(0,))(
    _level_step_robust_planar_body)
_scan_steps_robust_planar = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_robust_planar_body)

# the batch axis maps over the leading axis of (B, nnz, 2) vals; the same
# in_axes as the native twins apply
_level_step_planar_batched_body = jax.vmap(_level_step_planar_body,
                                           in_axes=_IN_AXES)
_scan_steps_planar_batched_body = jax.vmap(_scan_steps_planar_body,
                                           in_axes=_IN_AXES)
_level_step_planar_batched = partial(jax.jit, donate_argnums=(0,))(
    _level_step_planar_batched_body)
_scan_steps_planar_batched = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_planar_batched_body)
_level_step_robust_planar_batched_body = jax.vmap(
    _level_step_robust_planar_body, in_axes=_IN_AXES_ROBUST)
_scan_steps_robust_planar_batched_body = jax.vmap(
    _scan_steps_robust_planar_body, in_axes=_IN_AXES_ROBUST)
_level_step_robust_planar_batched = partial(jax.jit, donate_argnums=(0,))(
    _level_step_robust_planar_batched_body)
_scan_steps_robust_planar_batched = partial(jax.jit, donate_argnums=(0,))(
    _scan_steps_robust_planar_batched_body)


def _round_up(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def _build_pallas_layout(plan: FactorizePlan, seg, pad_key: int):
    """Host-side (D, R, C) segmented layout for one level (see kernels/ops)."""
    us = seg.upd_slice
    dst = plan.dst_col[us]
    li, ui, di = plan.lidx[us], plan.uidx[us], plan.didx[us]
    uniq, starts = np.unique(dst, return_index=True)
    starts = np.append(starts, len(dst))
    counts = np.diff(starts)
    D = len(uniq)
    R = _round_up(int(counts.max()) if D else 1, 256)
    col_start = plan.indptr[uniq].astype(np.int64)
    col_len = (plan.indptr[uniq + 1] - plan.indptr[uniq]).astype(np.int64)
    Cmax = int(col_len.max()) if D else 1
    C = _round_up(Cmax, 128) if Cmax <= 512 else _round_up(Cmax, 512)

    lidx2d = np.full((D, R), pad_key, dtype=np.int32)
    uidx2d = np.full((D, R), pad_key, dtype=np.int32)
    didx_local = np.full((D, R), C, dtype=np.int32)
    for r in range(D):
        s, e = starts[r], starts[r + 1]
        m = e - s
        lidx2d[r, :m] = li[s:e]
        uidx2d[r, :m] = ui[s:e]
        didx_local[r, :m] = di[s:e] - col_start[r]
    pos = col_start[:, None] + np.arange(C)[None, :]
    pos = np.where(np.arange(C)[None, :] < col_len[:, None], pos, pad_key)
    ns = seg.norm_slice
    pn = _pow2(seg.n_norm)
    return (
        jnp.asarray(_pad_to(plan.norm_idx[ns], pn, pad_key)),
        jnp.asarray(_pad_to(plan.norm_diag[ns], pn, pad_key)),
        jnp.asarray(lidx2d),
        jnp.asarray(uidx2d),
        jnp.asarray(didx_local),
        jnp.asarray(pos.astype(np.int32)),
    )


def _find_dense_tail(plan: FactorizePlan, min_size: int = 64,
                     max_size: int = 1024, density: float = 0.25):
    """Beyond-paper switch-to-dense: find a level suffix whose columns form a
    trailing [c*, n) block dense enough to finish with one blocked dense LU
    (the MXU replaces hundreds of tiny type-C levels).  Returns
    (level_cut, c_star) or None.

    Correctness: dependencies only point forward, updates from column j only
    write rows in L(j) (all >= c* when j >= c*), and the filled pattern is
    elimination-closed — so the dense block factorization is exact and
    entries outside the pattern stay identically zero (see DESIGN.md).
    """
    n = plan.n
    nlev = plan.num_levels
    if nlev < 4:
        return None
    lo, hi = max(n - max_size, 1), n - min_size
    if hi < lo:
        return None
    levels = plan.levels.levels.astype(np.int64)
    # clean column partition: columns [0,c) must all be in levels < l* and
    # columns [c,n) all in levels >= l* — otherwise a tail column would be
    # factorized twice (once sparsely, once densely)
    pmax = np.concatenate([[-1], np.maximum.accumulate(levels)])   # pmax[c]
    smin = np.minimum.accumulate(levels[::-1])[::-1]               # smin[c]
    cols = np.repeat(np.arange(n, dtype=np.int64), np.diff(plan.indptr))
    # entries inside the trailing [c, n) block are exactly those with
    # min(row, col) >= c: one histogram + suffix-sum covers every candidate
    m = np.minimum(cols, plan.indices.astype(np.int64))
    suffix = np.cumsum(np.bincount(m, minlength=n + 1)[::-1])[::-1]
    c = np.arange(lo, hi + 1, dtype=np.int64)
    size = n - c
    ok = (pmax[c] < smin[c]) & (suffix[c] / (size * size) >= density)
    idx = np.flatnonzero(ok)
    if not idx.size:
        return None
    c_star = int(c[idx[0]])    # smallest cut = largest qualifying tail
    return int(smin[c_star]), int(c_star)


def _build_dense_tail(plan: FactorizePlan, c_star: int, pad_key: int):
    """(positions (Np,Np) into vals, eye mask, Np) for the trailing block."""
    n = plan.n
    size = n - c_star
    Np = ((size + 127) // 128) * 128
    pos = np.full((Np, Np), pad_key, dtype=np.int32)
    for j in range(c_star, n):
        s, e = int(plan.indptr[j]), int(plan.indptr[j + 1])
        rows = plan.indices[s:e]
        m = rows >= c_star
        pos[rows[m] - c_star, j - c_star] = np.arange(s, e, dtype=np.int32)[m]
    eye = np.zeros((Np, Np), dtype=np.float32)
    ii = np.arange(size, Np)
    eye[ii, ii] = 1.0
    return jnp.asarray(pos), jnp.asarray(eye), Np


def _dense_tail_step_body(vals, pos, eye, *, interpret=True, use_pallas=False):
    dense = vals.at[pos].get(mode="fill", fill_value=0.0)
    dense = dense + eye.astype(vals.dtype)
    if use_pallas:
        from ..kernels.dense_lu import dense_lu

        dense = dense_lu(dense, interpret=interpret)
    else:
        from ..kernels.ref import dense_lu_ref

        dense = dense_lu_ref(dense)
    return vals.at[pos].set(dense, mode="drop")


_dense_tail_step = partial(
    jax.jit, donate_argnums=(0,), static_argnames=("interpret", "use_pallas"))(
    _dense_tail_step_body)


def _dense_tail_step_batched_body(vals, pos, eye):
    """Batched trailing block: gather (B, Np, Np), vmapped blocked LU,
    scatter back.  Always uses the XLA reference LU — the Pallas dense
    kernel stays a per-matrix dispatch on the unbatched path."""
    from ..kernels.ref import dense_lu_ref

    dense = vals.at[:, pos].get(mode="fill", fill_value=0.0)
    dense = dense + eye.astype(vals.dtype)[None]
    dense = jax.vmap(dense_lu_ref)(dense)
    return vals.at[:, pos].set(dense, mode="drop")


_dense_tail_step_batched = partial(jax.jit, donate_argnums=(0,))(
    _dense_tail_step_batched_body)


def _dense_tail_step_planar_body(vals, pos, eye, *, interpret=True,
                                 use_pallas=False):
    """Planar trailing block: gather (Np, Np, 2), factor the (2, Np, Np)
    plane pair (Pallas planar kernel or its XLA twin), scatter back.  The
    eye mask pads only the REAL plane — padded diagonal slots become 1+0j,
    exactly as on the native path."""
    dense = vals.at[pos].get(mode="fill", fill_value=0.0)
    dense = jnp.moveaxis(dense, -1, 0)
    dense = dense.at[0].add(eye.astype(dense.dtype))
    if use_pallas:
        from ..kernels.dense_lu import dense_lu_planar

        dense = dense_lu_planar(dense, interpret=interpret)
    else:
        from ..kernels.ref import dense_lu_planar_ref

        dense = dense_lu_planar_ref(dense)
    return vals.at[pos].set(jnp.moveaxis(dense, 0, -1), mode="drop")


_dense_tail_step_planar = partial(
    jax.jit, donate_argnums=(0,), static_argnames=("interpret", "use_pallas"))(
    _dense_tail_step_planar_body)


def _dense_tail_step_planar_batched_body(vals, pos, eye):
    from ..kernels.ref import dense_lu_planar_ref

    dense = vals.at[:, pos].get(mode="fill", fill_value=0.0)  # (B, Np, Np, 2)
    dense = jnp.moveaxis(dense, -1, 1)                        # (B, 2, Np, Np)
    dense = dense.at[:, 0].add(eye.astype(dense.dtype)[None])
    dense = jax.vmap(dense_lu_planar_ref)(dense)
    return vals.at[:, pos].set(jnp.moveaxis(dense, 1, -1), mode="drop")


_dense_tail_step_planar_batched = partial(jax.jit, donate_argnums=(0,))(
    _dense_tail_step_planar_batched_body)


@dataclasses.dataclass
class _Group:
    """One executor step: a scan-fused run, a single flat level, a
    Pallas-segmented level, or the dense trailing block."""

    kind: str      # "scan" | "flat" | "pallas" | "dense"
    arrays: tuple
    mode: str      # source level mode(s); "mixed" when a bucketed run fused
                   # levels of different modes (they execute identically on
                   # the non-Pallas path)
    # diag value indices of the columns this step factorizes ((K, Pc) for
    # scan groups, (Pc,) otherwise; padded with nnz) — the static-pivot
    # perturbation targets
    diag: object = None
    n_levels: int = 1


# --------------------------------------------------------------------------
# Whole-schedule fused program
# --------------------------------------------------------------------------
#
# The per-group dispatch loop (the ``jit_schedule=False`` path below) issues
# one jitted call per group — hundreds of host->device round-trips on long,
# narrow circuit schedules, exactly the launch overhead GLU3.0 amortizes
# with CUDA streams / pipelining.  ``_build_factorize_runner`` compiles the
# ENTIRE schedule (A-value scatter, every scan/flat/pallas/dense group, the
# static-pivot guard) into one jitted program, so a (re)factorization is a
# single device dispatch.  Runners are cached process-wide by plan digest +
# executor config (see core/executor.py).

def _schedule_step_bodies(planar: bool, batched: bool) -> dict:
    """The un-jitted step-body set for one (layout, batched) combination —
    the native and planar paths trace the same schedule through different
    arithmetic."""
    from ..kernels import ops as kops

    if planar:
        return dict(
            scan=(_scan_steps_planar_batched_body if batched
                  else _scan_steps_planar_body),
            scan_robust=(_scan_steps_robust_planar_batched_body if batched
                         else _scan_steps_robust_planar_body),
            flat=(_level_step_planar_batched_body if batched
                  else _level_step_planar_body),
            flat_robust=(_level_step_robust_planar_batched_body if batched
                         else _level_step_robust_planar_body),
            pallas=(kops.level_update_planar_batched_body if batched
                    else kops.level_update_planar_body),
            perturb=kops._perturb_diags_planar_body,
            dense=(_dense_tail_step_planar_batched_body if batched
                   else _dense_tail_step_planar_body),
        )
    return dict(
        scan=(_scan_steps_batched_body if batched else _scan_steps_body),
        scan_robust=(_scan_steps_robust_batched_body if batched
                     else _scan_steps_robust_body),
        flat=(_level_step_batched_body if batched else _level_step_body),
        flat_robust=(_level_step_robust_batched_body if batched
                     else _level_step_robust_body),
        pallas=(kops.level_update_batched_body if batched
                else kops.level_update_body),
        perturb=kops._perturb_diags_body,
        dense=(_dense_tail_step_batched_body if batched
               else _dense_tail_step_body),
    )


def _apply_schedule_groups(vals, groups, diags, tau, *, kinds, robust,
                           batched, interpret, use_pallas, planar=False):
    """Trace every group of the schedule in order; returns (vals, counts)
    where ``counts`` collects the per-group static-pivot bump counts
    (empty unless ``robust``)."""
    bodies = _schedule_step_bodies(planar, batched)

    def perturb(vals, diag, tau):
        if batched:
            return jax.vmap(bodies["perturb"],
                            in_axes=(0, None, 0))(vals, diag, tau)
        return bodies["perturb"](vals, diag, tau)

    counts = []
    for kind, arrs, diag in zip(kinds, groups, diags):
        if kind == "scan":
            if robust:
                vals, c = bodies["scan_robust"](vals, diag, tau, *arrs)
                counts.append(c)
            else:
                vals = bodies["scan"](vals, *arrs)
        elif kind == "pallas":
            if robust:
                vals, c = perturb(vals, diag, tau)
                counts.append(c)
            vals = bodies["pallas"](vals, *arrs, interpret=interpret)
        elif kind == "dense":
            if robust:
                vals, c = perturb(vals, diag, tau)
                counts.append(c)
            if batched:
                vals = bodies["dense"](vals, *arrs)
            else:
                vals = bodies["dense"](vals, *arrs, interpret=interpret,
                                       use_pallas=use_pallas)
        else:  # flat
            flat = tuple(a[0] for a in arrs)
            if robust:
                vals, c = bodies["flat_robust"](vals, diag, tau, *flat)
                counts.append(c)
            else:
                vals = bodies["flat"](vals, *flat)
    return vals, counts


def _build_factorize_runner(kinds, *, entry, batched, robust, interpret,
                            use_pallas, nnz, dtype, planar=False, shard=None):
    """One jitted program for the whole schedule.

    ``entry="scatter"`` takes A values (nnz_A,) / (B, nnz_A) plus the
    scatter map and builds the filled value array inside the program (no
    separate un-donated scatter dispatch); ``entry="filled"`` takes an
    already-filled (and donated) value array.  Returns ``vals`` — plus
    ``(a_max, n_perturbed)`` when the static-pivot guard is on.

    With ``planar`` the program runs on split re/im planes: a "scatter"
    entry takes logical (native complex) A values and packs them INSIDE the
    jitted program; a "filled" entry takes an already-planar (.., nnz, 2)
    array.  ``dtype`` is then the real plane/storage dtype.

    With ``shard`` (a :class:`~repro.distributed.ScenarioSharding`; batched
    entries only) the whole program is wrapped in ``shard_map``: the batch
    axis splits along the scenario mesh axes while the plan metadata
    (scatter map, group index arrays, diag targets) is replicated, so each
    shard runs the full fused schedule — ONE dispatch — on its B/n_shards
    slice.  Every per-matrix reduction (``a_max``, perturbation counts)
    stays within its own batch row, so the sharded result is bit-identical
    to the single-device batched program.  The robust path additionally
    returns the perturbation count summed across the whole (global) batch
    via an exact psum, so ladder diagnostics see one aggregate without a
    second dispatch.
    """

    def run(a, a_scatter, groups, diags, eps):
        if entry == "scatter":
            if planar:
                a = pack_planes(a, dtype)
            shape = ((a.shape[0], nnz) if batched else (nnz,))
            if planar:
                shape = shape + (2,)
            vals = jnp.zeros(shape, dtype=dtype)
            if batched:
                vals = vals.at[:, a_scatter].set(a)
            else:
                vals = vals.at[a_scatter].set(a)
        else:
            vals = a
        if robust:
            mag = pabs(vals) if planar else jnp.abs(vals)
            a_max = jnp.max(mag, axis=1) if batched else jnp.max(mag)
            tau = eps * a_max
        else:
            a_max = tau = None
        vals, counts = _apply_schedule_groups(
            vals, groups, diags, tau, kinds=kinds, robust=robust,
            batched=batched, interpret=interpret, use_pallas=use_pallas,
            planar=planar)
        if robust:
            if counts:
                n_pert = sum(counts)
            elif batched:
                n_pert = jnp.zeros(vals.shape[0], dtype=jnp.int32)
            else:
                n_pert = jnp.asarray(0, dtype=jnp.int32)
            if shard is not None:
                n_pert_global = psum_exact(jnp.sum(n_pert), shard.axis_names)
                return vals, a_max, n_pert, n_pert_global
            return vals, a_max, n_pert
        return vals

    donate = (0,) if entry == "filled" else ()
    if shard is None:
        return jax.jit(run, donate_argnums=donate)
    if not batched:
        raise ValueError("scenario sharding requires a batched entry")
    bspec = shard.spec
    # batch arg sharded along the scenario axes; plan metadata (scatter map,
    # group arrays, diag targets, eps) replicated — P() is a pytree-prefix
    # spec so it covers the nested group tuples (and None leaves) wholesale.
    in_specs = (bspec, P(), P(), P(), P())
    if robust:
        # per-matrix outputs stay batch-sharded; the psum'd global count is
        # replicated (identical on every shard, so check_rep=False is safe).
        out_specs = (bspec, bspec, bspec, P())
    else:
        out_specs = bspec
    mapped = shard_map(run, mesh=shard.mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    return jax.jit(mapped, donate_argnums=donate)


class JaxFactorizer:
    """Level-scheduled GLU3.0 numeric factorization, compiled once per plan.

    Parameters
    ----------
    plan: FactorizePlan
    dtype: value dtype (paper uses float32; float64 also supported — TPU
        scatter-add is deterministic so there is no atomics restriction)
    fuse_levels: scan-fuse runs of levels with equal padded shapes (the TPU
        analogue of reducing per-level kernel-launch overhead / CUDA streams)
    fuse_buckets: quantize level shapes to a small geometric ladder chosen
        from the plan's level-shape histogram before fusing, so long runs of
        NEAR-equal narrow levels still collapse into one ``lax.scan`` group
        (pad-index-``== nnz`` drop semantics make the over-padding bit-safe).
        Implies nothing when ``fuse_levels=False``.
    bucket_waste: per-axis over-padding bound for the bucket ladder — a
        level is never padded past ``bucket_waste ×`` its own pow2 pad
    jit_schedule: compile the whole schedule (scatter + every group) into
        ONE jitted program per plan digest so a factorization is a single
        device dispatch; ``False`` restores the per-group dispatch loop
    executable_cache: where whole-schedule programs are cached —
        ``"default"`` (process-wide cache, shared across GLU rebuilds on the
        same plan), an :class:`~repro.core.executor.ExecutableCache`, or
        ``None`` (private per-instance cache)
    use_pallas: route SEGMENTED/PANEL levels through the Pallas kernel
        (interpret mode on CPU; compiled on real TPUs)
    dense_tail: switch-to-dense (on by default): when a trailing column
        block is dense enough, the hundreds of tiny levels covering it are
        replaced by ONE blocked dense-LU group inside the same fused
        program — on fill-heavy ordered circuit matrices this converts the
        dominant share of scatter-add update triples into matmuls (a >3x
        end-to-end factorization win on the benchmark suite).  A no-op on
        patterns with no qualifying tail; disable for strictly
        sparse-schedule execution.
    layout: value-storage layout — ``"native"`` (default) stores values in
        their own dtype; ``"planar"`` stores complex values as split re/im
        planes ``(..., 2)`` of the matching real dtype so every kernel —
        including the Pallas SEGMENTED/PANEL and dense-tail kernels, which
        take no complex operands — computes the complex MAC on real
        operands; ``"auto"`` picks planar for complex dtypes.  Planar
        factors come back as ``(nnz, 2)`` / ``(B, nnz, 2)`` real arrays
        (``repro.sparse.unpack_planes`` recovers native complex).
    shard: optional :class:`~repro.distributed.ScenarioSharding` — batched
        factorizations shard the batch axis across the mesh (plan metadata
        replicated, one fused dispatch per shard); unbatched calls and
        batches not divisible by the shard count run the unsharded
        executable.  The ExecutableCache key carries the mesh descriptor so
        sharded and unsharded runners never collide.
    """

    def __init__(
        self,
        plan: FactorizePlan,
        dtype=jnp.float32,
        fuse_levels: bool = True,
        fuse_buckets: bool = True,
        bucket_waste: float = 4.0,
        jit_schedule: bool = True,
        executable_cache="default",
        use_pallas: bool = False,
        mode_override: Optional[str] = None,
        disable_modes: tuple = (),
        interpret: bool = True,
        dense_tail: bool = True,
        dense_tail_density: float = 0.25,
        static_pivot: Optional[float] = None,
        layout: str = "native",
        shard=None,
    ):
        self.plan = plan
        # Scenario sharding: batched entry points split the batch axis over
        # the shard's mesh (shard_map around the fused runner); unbatched
        # calls and non-divisible batches fall back to the unsharded
        # executable.  A 1-shard resolution degenerates to None.
        self.shard = shard if (shard is not None and shard.n_shards > 1) \
            else None
        self.dtype = dtype
        self.layout = resolve_layout(layout, dtype)
        self.storage_dtype = self.layout.storage_dtype
        # Why Pallas is (partially) off is surfaced instead of silently
        # downgraded: ``pallas_disabled_reason`` is None iff SEGMENTED/PANEL
        # levels and the dense tail run as compiled Pallas kernels.
        reason = None
        if not use_pallas:
            reason = "use_pallas=False"
        elif (np.issubdtype(np.dtype(dtype), np.complexfloating)
              and not self.layout.planar):
            # Pallas TPU kernels take no complex operands: with native
            # complex storage the SEGMENTED/PANEL levels (and the dense
            # tail) route through the equivalent flat XLA path.  Planar
            # re/im storage (layout="planar" or "auto") keeps them on the
            # Pallas path.
            use_pallas = False
            reason = ("complex dtype with layout='native' "
                      "(pass layout='planar' to keep Pallas kernels)")
        elif (mode_override is not None
              and mode_override not in (MODE_SEGMENTED, MODE_PANEL)):
            reason = (f"mode_override={mode_override!r} routes every level "
                      "off the Pallas path")
        elif MODE_SEGMENTED in disable_modes and MODE_PANEL in disable_modes:
            reason = "disable_modes removes every Pallas-eligible mode"
        elif interpret and jax.default_backend() == "tpu":
            reason = ("interpret=True runs interpreter-mode kernels on a "
                      "TPU backend")
        self.pallas_disabled_reason = reason
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._a_scatter = jnp.asarray(plan.a_scatter, dtype=jnp.int32)
        self.nnz = plan.nnz
        # static pivot perturbation: |diag| < static_pivot * max|A| is bumped
        # instead of dividing toward inf/NaN (None disables; the fast path
        # then runs the exact same jitted steps as before).  Granularity is
        # per level: each level's diagonals are final when its step starts.
        # The dense trailing block is the one exception — only its
        # pre-elimination diagonals are guarded; a pivot that turns tiny
        # *during* the in-tail dense elimination is not re-checked (combine
        # static_pivot with dense_tail=False if that guarantee matters).
        self.static_pivot = static_pivot
        self._diag_idx = jnp.asarray(plan.diag_idx, dtype=jnp.int32)
        self.last_a_max = None
        self.last_n_perturbed = None
        # global (cross-shard) perturbation count of the most recent sharded
        # robust factorization; None on unsharded paths
        self.last_n_perturbed_global = None

        pad_key = plan.nnz  # padding index == nnz -> drop/fill semantics
        self.dense_tail_info = None
        level_cut = plan.num_levels
        if dense_tail:
            found = _find_dense_tail(plan, density=dense_tail_density)
            if found is not None:
                level_cut, c_star = found
                pos, eye, Np = _build_dense_tail(plan, c_star, pad_key)
                self.dense_tail_info = dict(level_cut=level_cut, c_star=c_star,
                                            size=plan.n - c_star, padded=Np)
                self._dense_tail = (pos, eye)

        # Only the static-pivot guard needs per-group diag arrays; gating on
        # it keeps the plain path's fusion key to the level's padded shapes,
        # so enabling the guard is the only thing that can change grouping.
        robust = static_pivot is not None
        # Bucketed ragged fusion: quantize each axis's pow2 pad up to a
        # geometric ladder picked from the plan's level-shape histogram, so
        # levels only a factor <= bucket_waste apart share one scan shape.
        # Off the Pallas path all modes execute the same flat XLA step, so
        # bucketed runs also fuse ACROSS modes (group mode becomes "mixed").
        fuse_buckets = fuse_buckets and fuse_levels
        self.fuse_buckets = fuse_buckets
        buckets = plan.level_shape_buckets(bucket_waste) if fuse_buckets else None

        def _bucket(p: int, axis: str) -> int:
            return bucketize(p, buckets[axis]) if buckets is not None else p

        groups: list[_Group] = []
        run: list[tuple] = []
        run_diag: list[np.ndarray] = []
        run_modes: list[str] = []
        run_shape = None

        def _seg_diag(seg, pc: int) -> np.ndarray:
            return _pad_to(plan.diag_idx[seg.cols], pc, pad_key)

        def flush():
            nonlocal run, run_diag, run_modes, run_shape
            if not run:
                return
            stacked = tuple(
                jnp.asarray(np.stack([r[i] for r in run])) for i in range(5)
            )
            diag = None
            if robust:
                diag = jnp.asarray(np.stack(run_diag))
                if len(run) == 1:
                    diag = diag[0]
            mode = run_modes[0] if len(set(run_modes)) == 1 else "mixed"
            groups.append(
                _Group(kind="scan" if len(run) > 1 else "flat",
                       arrays=stacked, mode=mode, diag=diag,
                       n_levels=len(run))
            )
            run, run_diag, run_modes, run_shape = [], [], [], None

        for seg in plan.segments:
            if seg.level >= level_cut:
                break  # replaced by the dense trailing block
            mode = mode_override or seg.mode
            if mode in disable_modes:
                mode = MODE_FLAT if mode != MODE_FLAT else MODE_SEGMENTED
            if use_pallas and mode in (MODE_SEGMENTED, MODE_PANEL) and seg.n_upd:
                flush()
                groups.append(
                    _Group(kind="pallas",
                           arrays=_build_pallas_layout(plan, seg, pad_key),
                           mode=mode,
                           diag=(jnp.asarray(_seg_diag(seg, _pow2(len(seg.cols))))
                                 if robust else None))
                )
                continue
            ns, us = seg.norm_slice, seg.upd_slice
            pn = _bucket(_pow2(seg.n_norm), "norm")
            pu = _bucket(_pow2(seg.n_upd), "upd")
            pc = _bucket(_pow2(len(seg.cols)), "cols")
            arrs = (
                _pad_to(plan.norm_idx[ns], pn, pad_key),
                _pad_to(plan.norm_diag[ns], pn, pad_key),
                _pad_to(plan.lidx[us], pu, pad_key),
                _pad_to(plan.uidx[us], pu, pad_key),
                _pad_to(plan.didx[us], pu, pad_key),
            )
            if fuse_buckets:
                # execution is mode-agnostic here, so the key is shape-only
                shape = (pn, pu, pc) if robust else (pn, pu)
            else:
                shape = (pn, pu, pc, mode) if robust else (pn, pu, mode)
            if fuse_levels and shape == run_shape:
                run.append(arrs)
                if robust:
                    run_diag.append(_seg_diag(seg, pc))
                run_modes.append(mode)
            else:
                flush()
                run = [arrs]
                run_diag = [_seg_diag(seg, pc)] if robust else []
                run_modes = [mode]
                run_shape = shape
            if not fuse_levels:
                flush()
        flush()
        if self.dense_tail_info is not None:
            c_star = self.dense_tail_info["c_star"]
            tail_diag = None
            if robust:
                tail_diag = jnp.asarray(_pad_to(
                    plan.diag_idx[c_star:], _pow2(plan.n - c_star), pad_key))
            groups.append(_Group(kind="dense", arrays=self._dense_tail,
                                 mode="dense", diag=tail_diag))
        self._groups = groups

        # Static schedule signature + pytree views for the fused runner.
        self.jit_schedule = jit_schedule
        self._exec_cache = resolve_executable_cache(executable_cache)
        self._kinds = tuple(g.kind for g in groups)
        self._group_arrays = tuple(g.arrays for g in groups)
        self._group_diags = tuple(g.diag for g in groups)
        if self.shard is not None:
            # plan metadata gets an explicitly replicated NamedSharding so
            # the sharded runner never re-lays it out per call
            self._a_scatter = self.shard.replicate(self._a_scatter)
            self._group_arrays = self.shard.replicate(self._group_arrays)
            self._group_diags = self.shard.replicate(self._group_diags)
        self.n_groups = len(groups)
        # dispatch count of the most recent factorize* call (1 on the fused
        # path; one per jitted group call — plus entry scatter — otherwise)
        self.last_n_dispatches = 0

    # -- whole-schedule fused path -----------------------------------------

    def _shard_for_batch(self, batched: bool, batch: Optional[int]):
        """The ScenarioSharding to run under, or None: sharding applies only
        to batched entries whose batch divides the shard count (the facade
        pads; direct callers silently fall back, mirroring the
        silent-replicate rule in distributed/sharding.py)."""
        if self.shard is None or not batched:
            return None
        if batch is not None and batch % self.shard.n_shards != 0:
            return None
        return self.shard

    def _runner_key(self, entry: str, batched: bool, shard=None):
        robust = self.static_pivot is not None
        return ("factorize", self.plan.digest, entry, batched, self._kinds,
                np.dtype(self.dtype).str, robust, self.use_pallas,
                self.interpret, self.nnz,
                None if shard is None else shard.descriptor,
                self.layout.name)

    def _runner_for(self, entry: str, batched: bool, shard=None):
        robust = self.static_pivot is not None
        return self._exec_cache.get_or_build(
            self._runner_key(entry, batched, shard),
            lambda: _build_factorize_runner(
                self._kinds, entry=entry, batched=batched, robust=robust,
                interpret=self.interpret, use_pallas=self.use_pallas,
                nnz=self.nnz, dtype=self.storage_dtype,
                planar=self.layout.planar, shard=shard))

    def _factorize_fused(self, a, *, entry: str, batched: bool) -> jnp.ndarray:
        robust = self.static_pivot is not None
        shard = self._shard_for_batch(batched, a.shape[0] if batched else None)
        runner = self._runner_for(entry, batched, shard)
        eps = (jnp.asarray(self.static_pivot, dtype=self.storage_dtype)
               if robust else None)
        out = runner(a, self._a_scatter, self._group_arrays,
                     self._group_diags, eps)
        self.last_n_dispatches = 1
        self.last_n_perturbed_global = None
        if robust:
            if shard is not None:
                (vals, self.last_a_max, self.last_n_perturbed,
                 self.last_n_perturbed_global) = out
            else:
                vals, self.last_a_max, self.last_n_perturbed = out
        else:
            vals = out
            self.last_a_max = None
            self.last_n_perturbed = None
        return vals

    def _jitted_steps(self, batched: bool) -> dict:
        """Jitted per-group step functions for this layout (non-fused path)."""
        from ..kernels import ops as kops

        if self.layout.planar:
            if batched:
                return dict(
                    scan=_scan_steps_planar_batched,
                    scan_robust=_scan_steps_robust_planar_batched,
                    flat=_level_step_planar_batched,
                    flat_robust=_level_step_robust_planar_batched,
                    pallas=kops.level_update_planar_batched,
                    perturb=kops.perturb_diags_planar_batched,
                    dense=_dense_tail_step_planar_batched,
                )
            return dict(
                scan=_scan_steps_planar,
                scan_robust=_scan_steps_robust_planar,
                flat=_level_step_planar,
                flat_robust=_level_step_robust_planar,
                pallas=kops.level_update_planar,
                perturb=kops.perturb_diags_planar,
                dense=_dense_tail_step_planar,
            )
        if batched:
            return dict(
                scan=_scan_steps_batched, scan_robust=_scan_steps_robust_batched,
                flat=_level_step_batched, flat_robust=_level_step_robust_batched,
                pallas=kops.level_update_batched,
                perturb=kops.perturb_diags_batched,
                dense=_dense_tail_step_batched,
            )
        return dict(
            scan=_scan_steps, scan_robust=_scan_steps_robust,
            flat=_level_step, flat_robust=_level_step_robust,
            pallas=kops.level_update, perturb=kops.perturb_diags,
            dense=_dense_tail_step,
        )

    def factorize(self, a_vals) -> jnp.ndarray:
        """Scatter A values into the filled pattern and factorize in place."""
        a = jnp.asarray(a_vals, dtype=self.dtype)
        if self.jit_schedule:
            # scatter folded into the fused program: no separate un-donated
            # nnz-sized zeros+set dispatch per refactorization (planar
            # layouts also pack re/im planes inside the program)
            return self._factorize_fused(a, entry="scatter", batched=False)
        if self.layout.planar:
            a = pack_planes(a, self.storage_dtype)
        vals = jnp.zeros(self.layout.storage_shape(self.nnz),
                         dtype=self.storage_dtype)
        vals = vals.at[self._a_scatter].set(a)
        out = self.factorize_filled(vals)
        self.last_n_dispatches += 1     # the entry scatter
        return out

    def factorize_filled(self, vals: jnp.ndarray) -> jnp.ndarray:
        if self.jit_schedule:
            return self._factorize_fused(
                jnp.asarray(vals, dtype=self.storage_dtype), entry="filled",
                batched=False)
        step = self._jitted_steps(batched=False)
        robust = self.static_pivot is not None
        self.last_n_perturbed_global = None
        n_dispatch = 0
        if robust:
            mag = pabs(vals) if self.layout.planar else jnp.abs(vals)
            self.last_a_max = a_max = jnp.max(mag)
            tau = jnp.asarray(self.static_pivot,
                              dtype=self.storage_dtype) * a_max
            counts = []
            n_dispatch += 1
        else:
            # no extra dispatch on the plain hot path; diagnostics that
            # need max|A| recompute it lazily from the caller's retained
            # A values (GLU.solve_info does)
            self.last_a_max = None
            self.last_n_perturbed = None
        for g in self._groups:
            if g.kind == "scan":
                if robust:
                    vals, c = step["scan_robust"](vals, g.diag, tau, *g.arrays)
                    counts.append(c)
                else:
                    vals = step["scan"](vals, *g.arrays)
                n_dispatch += 1
            elif g.kind == "pallas":
                if robust:
                    vals, c = step["perturb"](vals, g.diag, tau)
                    counts.append(c)
                    n_dispatch += 1
                vals = step["pallas"](vals, *g.arrays, interpret=self.interpret)
                n_dispatch += 1
            elif g.kind == "dense":
                if robust:
                    vals, c = step["perturb"](vals, g.diag, tau)
                    counts.append(c)
                    n_dispatch += 1
                vals = step["dense"](vals, *g.arrays, interpret=self.interpret,
                                     use_pallas=self.use_pallas)
                n_dispatch += 1
            else:
                if robust:
                    vals, c = step["flat_robust"](vals, g.diag, tau,
                                                  *(a[0] for a in g.arrays))
                    counts.append(c)
                else:
                    vals = step["flat"](vals, *(a[0] for a in g.arrays))
                n_dispatch += 1
        if robust:
            self.last_n_perturbed = sum(counts) if counts \
                else jnp.asarray(0, dtype=jnp.int32)
        self.last_n_dispatches = n_dispatch
        return vals

    # -- batched refactorization (one plan, many matrices) -------------------
    def factorize_batched(self, a_vals_batch) -> jnp.ndarray:
        """Factorize B matrices sharing this plan's pattern in lockstep.

        ``a_vals_batch``: (B, nnz_A) values, one row per matrix, in A's
        entry order.  Returns (B, nnz_filled) factored values — row ``i``
        equals ``factorize(a_vals_batch[i])``.  Every level-group runs as a
        single device dispatch for the whole batch.
        """
        a = jnp.asarray(a_vals_batch, dtype=self.dtype)
        if a.ndim != 2:
            raise ValueError(f"expected (B, nnz_A) values, got shape {a.shape}")
        if self.jit_schedule:
            return self._factorize_fused(a, entry="scatter", batched=True)
        if self.layout.planar:
            a = pack_planes(a, self.storage_dtype)
        vals = jnp.zeros(self.layout.storage_shape(a.shape[0], self.nnz),
                         dtype=self.storage_dtype)
        vals = vals.at[:, self._a_scatter].set(a)
        out = self.factorize_filled_batched(vals)
        self.last_n_dispatches += 1     # the entry scatter
        return out

    def factorize_filled_batched(self, vals: jnp.ndarray) -> jnp.ndarray:
        if self.jit_schedule:
            return self._factorize_fused(
                jnp.asarray(vals, dtype=self.storage_dtype), entry="filled",
                batched=True)
        step = self._jitted_steps(batched=True)
        robust = self.static_pivot is not None
        self.last_n_perturbed_global = None
        n_dispatch = 0
        if robust:
            mag = pabs(vals) if self.layout.planar else jnp.abs(vals)
            self.last_a_max = jnp.max(mag, axis=1)  # (B,)
            tau = jnp.asarray(self.static_pivot,
                              dtype=self.storage_dtype) * self.last_a_max
            counts = []
            n_dispatch += 1
        else:
            self.last_a_max = None
            self.last_n_perturbed = None
        for g in self._groups:
            if g.kind == "scan":
                if robust:
                    vals, c = step["scan_robust"](vals, g.diag, tau, *g.arrays)
                    counts.append(c)
                else:
                    vals = step["scan"](vals, *g.arrays)
                n_dispatch += 1
            elif g.kind == "pallas":
                if robust:
                    vals, c = step["perturb"](vals, g.diag, tau)
                    counts.append(c)
                    n_dispatch += 1
                vals = step["pallas"](vals, *g.arrays, interpret=self.interpret)
                n_dispatch += 1
            elif g.kind == "dense":
                if robust:
                    vals, c = step["perturb"](vals, g.diag, tau)
                    counts.append(c)
                    n_dispatch += 1
                vals = step["dense"](vals, *g.arrays)
                n_dispatch += 1
            else:
                if robust:
                    vals, c = step["flat_robust"](vals, g.diag, tau,
                                                  *(a[0] for a in g.arrays))
                    counts.append(c)
                else:
                    vals = step["flat"](vals, *(a[0] for a in g.arrays))
                n_dispatch += 1
        if robust:
            self.last_n_perturbed = sum(counts) if counts \
                else jnp.zeros(vals.shape[0], dtype=jnp.int32)
        self.last_n_dispatches = n_dispatch
        return vals

    __call__ = factorize
