"""FactorizePlan: host-side compilation of the symbolic analysis into flat
per-level index arrays the numeric executors consume.

The plan is built once per sparsity pattern and reused across
refactorizations (the SPICE/Newton-Raphson use case the paper targets).

Per level ℓ the numeric step is:

  1. normalisation   vals[norm_idx] /= vals[norm_diag]        (L of level cols)
  2. submatrix update vals[didx]   -= vals[lidx] * vals[uidx] (all updates whose
                                                               *source* column
                                                               is in level ℓ)

Update triples are stored sorted by (level, destination column) so that the
segmented Pallas kernel can process contiguous per-destination runs, and the
flat XLA executor can slice a level in O(1).

Padding convention: all padded index slots hold ``nnz`` (one past the value
array); executors gather with ``mode='fill'`` and scatter with
``mode='drop'`` so padding is inert — no scratch slot, no NaNs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..sparse.csc import concat_ranges as _concat_ranges
from ..sparse.csc import csc_transpose_pattern, pattern_digest
from .dependency import Levelization, levelize_relaxed, longest_path_levels
from .symbolic import FilledPattern

__all__ = ["FactorizePlan", "LevelSegment", "build_plan", "reach_closure",
           "pow2_pad", "choose_buckets", "bucketize",
           "MODE_FLAT", "MODE_SEGMENTED", "MODE_PANEL"]

MODE_FLAT = "flat"            # one fused scatter-add (type A levels)
MODE_SEGMENTED = "segmented"  # Pallas per-destination-column kernel (type B)
MODE_PANEL = "panel"          # few long columns: per-column dense panel (type C)


@dataclasses.dataclass
class LevelSegment:
    """One level's numeric work (unpadded views into the plan arrays)."""

    level: int
    cols: np.ndarray        # columns factorised at this level
    norm_slice: slice       # into norm_idx / norm_diag
    upd_slice: slice        # into lidx / uidx / didx (and dst_col)
    mode: str

    @property
    def n_norm(self) -> int:
        return self.norm_slice.stop - self.norm_slice.start

    @property
    def n_upd(self) -> int:
        return self.upd_slice.stop - self.upd_slice.start


# --------------------------------------------------------------------------
# Padded-shape buckets (ragged level fusion)
# --------------------------------------------------------------------------
#
# Executors pad every level's index arrays to a power of two so that levels
# with equal padded shapes can fuse into one ``lax.scan``.  Exact-pow2
# matching breaks a long run of *near*-equal narrow levels into many groups
# (one per distinct pow2 class) — the per-group dispatch overhead GLU3.0
# identifies as the bottleneck on long, narrow schedules.  Quantizing the
# padded shapes to a small geometric bucket ladder chosen from the plan's
# level-shape histogram lets those runs share one shape.  Over-padding is
# bit-inert by the plan's padding convention (index ``nnz`` gathers fill
# values and scatters with drop), so the only cost is bounded wasted lanes.

def pow2_pad(x: int, lo: int = 8) -> int:
    """Smallest power of two >= ``x`` (at least ``lo``)."""
    return max(lo, 1 << (int(x - 1).bit_length())) if x > 0 else lo


def choose_buckets(sizes, max_waste: float = 4.0, lo: int = 8,
                   pad_slack: int = 1024,
                   work_budget: float = 1.25) -> np.ndarray:
    """Work-aware geometric bucket ladder covering ``sizes``.

    Buckets are a subset of the pow2-padded sizes actually present, always
    including the largest.  Walking the ladder from the top, a rung is
    dropped (its levels round up to the next kept rung) only when all of:

    * per-level inflation stays within ``max_waste``x its own pow2 pad,
    * the step to the next kept rung is small in absolute terms
      (``<= pad_slack`` elements per level) — narrow levels always fuse —
      OR dropping it is globally cheap: the total extra padded elements
      across the histogram stay within ``work_budget``x the exact
      pow2-padded total.

    So the long runs of near-equal narrow levels that dominate circuit
    schedules collapse to one or two buckets, while the few wide levels
    that carry the real update work keep their exact pow2 shapes instead
    of multiplying it.
    """
    padded = np.asarray([pow2_pad(int(s), lo)
                         for s in np.asarray(sizes).ravel()], dtype=np.int64)
    if padded.size == 0:
        return np.asarray([lo], dtype=np.int64)
    uniq, counts = np.unique(padded, return_counts=True)
    total = int((uniq * counts).sum())
    budget = (work_budget - 1.0) * total
    kept = [int(uniq[-1])]
    spent = 0.0
    for p, c in zip(uniq[:-1][::-1], counts[:-1][::-1]):
        p, c = int(p), int(c)
        extra = (kept[-1] - p) * c
        cheap = (kept[-1] - p) <= pad_slack or spent + extra <= budget
        if kept[-1] <= max_waste * p and cheap:
            spent += extra
            continue
        kept.append(p)
    return np.asarray(sorted(kept), dtype=np.int64)


def bucketize(size: int, buckets) -> int:
    """Smallest bucket >= ``size`` (clamped to the largest bucket)."""
    buckets = np.asarray(buckets)
    i = int(np.searchsorted(buckets, int(size)))
    return int(buckets[min(i, len(buckets) - 1)])


def reach_closure(n: int, adj_ptr: np.ndarray, adj_rows: np.ndarray,
                  seeds: np.ndarray) -> np.ndarray:
    """Transitive closure of ``seeds`` under the DAG ``col j -> adj_rows
    [adj_ptr[j]:adj_ptr[j+1]]``, as a sorted index array.

    This is the Gilbert-Peierls reach computation driving sparse-RHS
    triangular solves (Ruipeng Li, arXiv 1710.04985): the nonzero set of
    ``L^{-1} b`` is exactly the closure of ``nonzeros(b)`` under L's
    below-diagonal adjacency.  Frontier-batched BFS, same discipline as the
    vectorized symbolic engine: one ranged-concat gather per wave."""
    seeds = np.unique(np.asarray(seeds, dtype=np.int64))
    if seeds.size and (seeds[0] < 0 or seeds[-1] >= n):
        raise ValueError(f"rhs pattern indices out of range [0, {n})")
    visited = np.zeros(n, dtype=bool)
    visited[seeds] = True
    frontier = seeds
    while frontier.size:
        cand = adj_rows[_concat_ranges(adj_ptr[frontier],
                                       adj_ptr[frontier + 1])]
        cand = np.unique(cand[~visited[cand]])
        visited[cand] = True
        frontier = cand
    return np.flatnonzero(visited)


@dataclasses.dataclass
class FactorizePlan:
    n: int
    nnz: int
    indptr: np.ndarray
    indices: np.ndarray
    diag_idx: np.ndarray          # (n,) flat value index of each diagonal
    levels: Levelization
    # normalisation arrays, concatenated in level order
    norm_idx: np.ndarray
    norm_diag: np.ndarray
    # update triples, sorted by (level, destination column)
    lidx: np.ndarray
    uidx: np.ndarray
    didx: np.ndarray
    dst_col: np.ndarray
    segments: list[LevelSegment]
    a_scatter: np.ndarray         # original A entry -> filled value index
    # trisolve plans
    fwd_rows: np.ndarray          # L entry row i
    fwd_cols: np.ndarray          # L entry col j
    fwd_vidx: np.ndarray          # L entry value index
    fwd_ptr: np.ndarray           # per-L-level offsets into fwd_* (by level of j)
    bwd_rows: np.ndarray
    bwd_cols: np.ndarray
    bwd_vidx: np.ndarray
    bwd_ptr: np.ndarray
    bwd_level_cols: np.ndarray    # columns ordered by U-level
    bwd_col_ptr: np.ndarray
    # sparse-RHS reach machinery: CSR-ish DAG adjacency of L (below-diagonal
    # rows per column) and U (above-diagonal rows per column), computed at
    # plan time so per-pattern reach closures are pure index walks
    l_adj_ptr: np.ndarray
    l_adj_rows: np.ndarray
    u_adj_ptr: np.ndarray
    u_adj_rows: np.ndarray
    # content address of this plan (pattern + levelization): the key under
    # which whole-schedule executables are cached process-wide, so two
    # executors built on equal plans share one compiled program
    digest: str = ""

    def fwd_reach(self, nonzeros) -> np.ndarray:
        """Columns of ``y = L^{-1} b`` that can be nonzero when ``b`` is
        supported on ``nonzeros`` (sorted index array)."""
        return reach_closure(self.n, self.l_adj_ptr, self.l_adj_rows,
                             nonzeros)

    def bwd_reach(self, nonzeros) -> np.ndarray:
        """Rows of ``x = U^{-1} y`` that can be nonzero when ``y`` is
        supported on ``nonzeros`` (sorted index array)."""
        return reach_closure(self.n, self.u_adj_ptr, self.u_adj_rows,
                             nonzeros)

    @property
    def num_levels(self) -> int:
        return self.levels.num_levels

    @property
    def total_updates(self) -> int:
        return len(self.lidx)

    def flops(self) -> int:
        """2 flops per MAC update + 1 per normalisation division."""
        return 2 * len(self.lidx) + len(self.norm_idx)

    def verify(self, pattern=None, **kwargs):
        """Run the static plan sanitizer (:func:`repro.analysis.verify_plan`)
        on this plan and return the :class:`~repro.analysis.VerifyReport`.
        The plan's own filled pattern is the default reference."""
        from ..analysis import verify_plan   # lazy: analysis imports core

        return verify_plan(self, pattern, **kwargs)

    def level_shape_buckets(self, max_waste: float = 4.0) -> dict:
        """Per-dimension pad-bucket ladders from the plan's level-shape
        histogram: ``norm`` (normalisation entries), ``upd`` (update
        triples) and ``cols`` (columns per level).  Executors quantize each
        level's pow2-padded shapes to these buckets so long runs of
        near-equal levels fuse into one scan group."""
        segs = self.segments
        return {
            "norm": choose_buckets([s.n_norm for s in segs], max_waste),
            "upd": choose_buckets([s.n_upd for s in segs], max_waste),
            "cols": choose_buckets([len(s.cols) for s in segs], max_waste),
        }


def _mode_for_level(n_cols: int, n_upd: int, panel_threshold: int) -> str:
    """Paper Fig. 10 mode criteria: wide levels are type A (flat
    scatter-add), and the narrow ones split on *update volume*, not column
    count alone — a narrow level whose few columns carry a huge update load
    (long fill-heavy columns near the root of the etree) is type B
    (segmented per-destination accumulation), while a narrow level with
    genuinely small per-column work is type C (dense panel)."""
    if n_cols > 4 * panel_threshold:
        return MODE_FLAT
    if n_cols <= panel_threshold and n_upd <= 32 * panel_threshold * n_cols:
        return MODE_PANEL
    return MODE_SEGMENTED


def build_plan(
    As: FilledPattern,
    lv: Optional[Levelization] = None,
    panel_threshold: int = 16,
) -> FactorizePlan:
    n, indptr, indices = As.n, As.indptr.astype(np.int64), As.indices
    if lv is None:
        lv = levelize_relaxed(As)
    levels = lv.levels.astype(np.int64)

    # diagonal positions: one flat searchsorted over column-major (col, row)
    # keys, which are globally sorted for a CSC pattern
    cols_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    fkeys = cols_of * n + indices.astype(np.int64)
    diag_pos = np.searchsorted(fkeys, np.arange(n, dtype=np.int64) * (n + 1))
    bad = diag_pos >= len(fkeys)
    bad[~bad] = fkeys[diag_pos[~bad]] != np.arange(n, dtype=np.int64)[~bad] * (n + 1)
    if bad.any():
        j = int(np.flatnonzero(bad)[0])
        raise ValueError(f"zero diagonal at column {j} (run MC64 first)")
    l_start = diag_pos + 1
    l_end = indptr[1:]
    nnz_l = (l_end - l_start).astype(np.int64)

    # --- normalisation arrays grouped by level -----------------------------
    order = lv.order.astype(np.int64)
    norm_idx = _concat_ranges(l_start[order], l_end[order])
    norm_diag = np.repeat(diag_pos[order], nnz_l[order])
    norm_counts = np.zeros(lv.num_levels, dtype=np.int64)
    np.add.at(norm_counts, levels[order.astype(np.int64)], nnz_l[order])
    norm_ptr = np.concatenate([[0], np.cumsum(norm_counts)])

    # --- update triples, destination-column major --------------------------
    # one bulk pass over all U entries: the per-destination-column loop is a
    # gather (U entry -> source column) + ranged concat (source L rows) +
    # one flat searchsorted into the global (col, row) key array
    u_flat = _concat_ranges(indptr[:-1], diag_pos)   # U entries, col-major
    jj = indices[u_flat].astype(np.int64)            # source column per U entry
    cnt = nnz_l[jj]
    lidx = _concat_ranges(l_start[jj], l_end[jj])
    uidx = np.repeat(u_flat, cnt)
    dst = np.repeat(cols_of[u_flat], cnt)
    didx = np.searchsorted(fkeys, dst * n + indices[lidx].astype(np.int64))
    lev = np.repeat(levels[jj], cnt)
    srt = np.argsort(lev, kind="stable")  # within level: dst ascending
    lidx, uidx, didx, lev, dst = lidx[srt], uidx[srt], didx[srt], lev[srt], dst[srt]
    upd_ptr = np.searchsorted(lev, np.arange(lv.num_levels + 1))

    segments = []
    for l in range(lv.num_levels):
        cols = lv.columns_at(l)
        nu = int(upd_ptr[l + 1] - upd_ptr[l])
        segments.append(
            LevelSegment(
                level=l,
                cols=cols,
                norm_slice=slice(int(norm_ptr[l]), int(norm_ptr[l + 1])),
                upd_slice=slice(int(upd_ptr[l]), int(upd_ptr[l + 1])),
                mode=_mode_for_level(len(cols), nu, panel_threshold),
            )
        )

    # --- forward trisolve plan (L levels == factorisation levels) ----------
    all_cols_l = np.repeat(np.arange(n, dtype=np.int64), nnz_l)
    fwd_vidx = _concat_ranges(l_start, l_end)
    fwd_rows = indices[fwd_vidx].astype(np.int64)
    fwd_cols = all_cols_l
    # reach adjacency of the L DAG: captured column-major, before level sort
    l_adj_ptr = np.concatenate([[0], np.cumsum(nnz_l)]).astype(np.int64)
    l_adj_rows = fwd_rows.copy()
    fwd_lev = levels[fwd_cols]
    srt = np.argsort(fwd_lev, kind="stable")
    fwd_rows, fwd_cols, fwd_vidx, fwd_lev = (
        fwd_rows[srt], fwd_cols[srt], fwd_vidx[srt], fwd_lev[srt])
    fwd_ptr = np.searchsorted(fwd_lev, np.arange(lv.num_levels + 1))

    # --- backward trisolve plan (U levels, computed descending) ------------
    # ulev[j] = longest chain through row entries k > j; mirroring indices
    # (j -> n-1-j) turns it into the standard src < dst longest-path problem
    indptr_t, indices_t, pos_t = csc_transpose_pattern(n, As.indptr, As.indices)
    rows_t = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr_t))
    um = indices_t > rows_t
    ulev = longest_path_levels(
        n, n - 1 - indices_t[um].astype(np.int64), n - 1 - rows_t[um])[::-1].copy()
    nulev = int(ulev.max()) + 1 if n else 0
    u_start = indptr[:-1]
    u_end = diag_pos  # strictly-above-diagonal entries
    nnz_u = (u_end - u_start).astype(np.int64)
    bwd_vidx = _concat_ranges(u_start, u_end)
    bwd_rows = indices[bwd_vidx].astype(np.int64)
    bwd_cols = np.repeat(np.arange(n, dtype=np.int64), nnz_u)
    # reach adjacency of the U DAG, same column-major capture
    u_adj_ptr = np.concatenate([[0], np.cumsum(nnz_u)]).astype(np.int64)
    u_adj_rows = bwd_rows.copy()
    bwd_lev = ulev[bwd_cols]
    srt = np.argsort(bwd_lev, kind="stable")
    bwd_rows, bwd_cols, bwd_vidx, bwd_lev = (
        bwd_rows[srt], bwd_cols[srt], bwd_vidx[srt], bwd_lev[srt])
    bwd_ptr = np.searchsorted(bwd_lev, np.arange(nulev + 1))
    col_order = np.argsort(ulev, kind="stable").astype(np.int64)
    bwd_col_ptr = np.searchsorted(ulev[col_order], np.arange(nulev + 1))

    return FactorizePlan(
        n=n,
        nnz=As.nnz,
        indptr=As.indptr,
        indices=indices,
        diag_idx=diag_pos,
        levels=lv,
        norm_idx=norm_idx,
        norm_diag=norm_diag,
        lidx=lidx,
        uidx=uidx,
        didx=didx,
        dst_col=dst,
        segments=segments,
        a_scatter=As.a_scatter,
        fwd_rows=fwd_rows,
        fwd_cols=fwd_cols,
        fwd_vidx=fwd_vidx,
        fwd_ptr=fwd_ptr,
        bwd_rows=bwd_rows,
        bwd_cols=bwd_cols,
        bwd_vidx=bwd_vidx,
        bwd_ptr=bwd_ptr,
        bwd_level_cols=col_order,
        bwd_col_ptr=bwd_col_ptr,
        l_adj_ptr=l_adj_ptr,
        l_adj_rows=l_adj_rows,
        u_adj_ptr=u_adj_ptr,
        u_adj_rows=u_adj_rows,
        digest=pattern_digest(As.indptr, indices, levels, order,
                              int(panel_threshold)),
    )
