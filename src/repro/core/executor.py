"""Process-wide cache of whole-schedule jitted executables.

The single-dispatch executors (:class:`~repro.core.factorize.JaxFactorizer`
and :class:`~repro.core.triangular.JaxTriangularSolver`) compile one XLA
program per schedule — scatter plus every level group in one device
dispatch.  Those programs are expensive to build and independent of the
executor *instance*: two ``GLU`` objects on the same symbolic plan (a
Newton re-scaling rebuild, a sweep corner, a second serving tenant) run
byte-identical schedules.  This cache keys the jitted callables by

  (executor kind, plan digest, entry point, batched, group-kind tuple,
   dtype, robust, use_pallas, interpret, value layout, shard descriptor,
   ...)

The value-layout field keeps native-complex and planar re/im-plane
programs apart — same plan, same dtype string, different array shapes and
arithmetic.  The shard descriptor (mesh shape + device ids + scenario
axes, or None) keeps shard_map-wrapped batch-parallel programs apart from
single-device ones — and programs on different meshes apart from each
other.

so the second construction compiles nothing: it reuses the same callable
object, whose ``jax.jit`` cache already holds the compiled executable for
the schedule's array shapes.  It is the executable-level sibling of the
symbolic :class:`~repro.core.planner.PlanCache` — plans deduplicate host
preprocessing, this deduplicates device compilation.

Eviction drops the callable (and with it the compiled XLA program); a
subsequent request rebuilds and recompiles.  The default capacity is far
above any realistic number of live (plan, config) pairs.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Hashable

__all__ = [
    "ExecutableCache",
    "ExecutableCacheStats",
    "default_executable_cache",
    "set_default_executable_cache",
]


@dataclasses.dataclass
class ExecutableCacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0
    evictions: int = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


class ExecutableCache:
    """LRU of whole-schedule jitted callables, keyed by hashable tuples."""

    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._fns: OrderedDict[Hashable, Callable] = OrderedDict()
        self._lock = threading.Lock()
        self.stats = ExecutableCacheStats()

    def get_or_build(self, key: Hashable, builder: Callable[[], Callable]):
        """The cached callable for ``key``, building (and caching) it via
        ``builder()`` on a miss."""
        with self._lock:
            fn = self._fns.get(key)
            if fn is not None:
                self._fns.move_to_end(key)
                self.stats.hits += 1
                return fn
            self.stats.misses += 1
        fn = builder()             # build outside the lock (it may trace)
        with self._lock:
            existing = self._fns.get(key)
            if existing is not None:    # racing builder won; keep its fn
                self._fns.move_to_end(key)
                return existing
            self.stats.builds += 1
            self._fns[key] = fn
            while len(self._fns) > self.capacity:
                self._fns.popitem(last=False)
                self.stats.evictions += 1
            return fn

    def clear(self) -> None:
        with self._lock:
            self._fns.clear()

    def keys(self) -> list:
        """Snapshot of the cached keys, most recently used last (the jaxpr
        audit uses this to locate a solver's fused runner)."""
        with self._lock:
            return list(self._fns)

    def __len__(self) -> int:
        return len(self._fns)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._fns


_default_cache = ExecutableCache()


def default_executable_cache() -> ExecutableCache:
    """The process-wide cache the executors use by default."""
    return _default_cache


def set_default_executable_cache(cache: ExecutableCache) -> ExecutableCache:
    """Swap the process-wide default cache; returns the previous one."""
    global _default_cache
    old = _default_cache
    _default_cache = cache
    return old


def resolve_executable_cache(cache):
    """``"default"`` -> the process-wide cache; ``None`` -> no caching
    (a private throwaway cache); an :class:`ExecutableCache` passes
    through."""
    if cache == "default":
        return _default_cache
    if cache is None:
        return ExecutableCache()
    if isinstance(cache, ExecutableCache):
        return cache
    raise TypeError(
        f"executable_cache must be an ExecutableCache, 'default' or None, "
        f"got {cache!r}")
