"""GLU facade: the paper's full flow (Fig. 5) behind one class.

  A -> MC64 (max-product matching + Dr/Dc scaling) -> fill-reducing
  ordering -> symbolic fill-in -> relaxed dependency detection +
  levelization -> plan -> (re)factorize on device -> triangular solve
  (+ optional batched iterative refinement)

All host-side preprocessing lives in the planner subsystem
(:mod:`repro.core.planner`): construction asks it for a
:class:`~repro.core.planner.SymbolicPlan` — by default through the
process-wide content-addressed plan cache, so re-constructing on a pattern
that was already analyzed (a Newton re-scaling rebuild, a sweep corner, a
repeated benchmark) performs zero symbolic work (``plan_from_cache`` reports
which path was taken).  ``GLU.from_plan`` consumes a prebuilt plan directly;
``factorize``/``solve`` are the fast repeated path (SPICE Newton iterations
reuse the plan).

Permutation algebra: with row_map/col_map (old -> new),
``A_perm[row_map[i], col_map[j]] = A[i, j]`` and solving ``A x = b`` becomes
``A_perm x_perm = b_perm`` with ``b_perm = b[inv_row_map]`` and
``x = x_perm[col_map]``.

Scaling algebra: the device actually factorizes ``B = Dr A Dc`` (every
scaled entry <= 1 in magnitude, matched diagonal exactly 1 — the Duff-Koster
guarantee no-pivot LU relies on).  ``A x = b`` becomes ``B y = Dr b`` with
``x = Dc y``; both transforms are diagonal and exact to one rounding each.
The componentwise backward error max_i |r_i| / (|A||x| + |b|)_i is invariant
under both row and column scaling, so the refinement stopping test on the
scaled system is the same test on the original one.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

import jax.numpy as jnp

from ..distributed.scenario import make_scenario_sharding
from ..sparse.csc import CSC
from ..sparse.layout import resolve_layout, unpack_planes
from .factorize import JaxFactorizer
from .planner import (
    MC64Scaling,
    SymbolicPlan,
    compute_scaling,
    plan_factorization,
)
from .triangular import JaxTriangularSolver

__all__ = ["GLU", "resolve_value_dtype"]


def resolve_value_dtype(dtype) -> np.dtype:
    """Resolve the *effective* value dtype JAX will actually use.

    Without 64-bit mode (``JAX_ENABLE_X64`` / ``jax.config.update
    ("jax_enable_x64", True)``) JAX silently truncates float64 -> float32
    and complex128 -> complex64.  Silent truncation on the solve path is a
    correctness bug (observed: residual 4.5e-7 on a float64 request), so a
    truncated request raises instead of warning-and-degrading.
    """
    requested = np.dtype(dtype)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        effective = jnp.empty(0, dtype=dtype).dtype
    if np.dtype(effective) != requested:
        raise ValueError(
            f"requested value dtype {requested} would be silently truncated "
            f"to {effective} because JAX 64-bit mode is disabled; set "
            f"JAX_ENABLE_X64=1 (or jax.config.update('jax_enable_x64', "
            f"True)) before importing jax, or request dtype={effective} "
            f"explicitly")
    return requested


class GLU:
    def __init__(
        self,
        A: CSC,
        ordering: str = "auto",
        symbolic: str = "auto",
        dtype=jnp.float64,
        mc64="scale",
        fuse_levels: bool = True,
        fuse_buckets: bool = True,
        bucket_waste: float = 4.0,
        jit_schedule: bool = True,
        executable_cache="default",
        use_pallas: bool = False,
        panel_threshold: int = 16,
        static_pivot: Optional[float] = None,
        refine: int = 0,
        refine_tol: Optional[float] = None,
        dense_tail: bool = True,
        dense_tail_density: float = 0.25,
        mode_override: Optional[str] = None,
        interpret: bool = True,
        plan_cache="default",
        layout: str = "auto",
        mesh=None,
        verify: str = "off",
    ):
        """``mc64``: ``"scale"``/``True`` — full Duff-Koster max-product
        matching with Dr/Dc scalings; ``"structural"`` — zero-free diagonal
        only (no scaling); ``"none"``/``False`` — identity.

        ``static_pivot``: relative threshold eps for the SuperLU_DIST-style
        pivot guard — any |diag| < eps * max|A| is bumped instead of
        producing inf/NaN (None disables).

        ``refine``: default number of iterative-refinement steps applied by
        ``solve``/``solve_batched`` (overridable per call); ``refine_tol``
        is the componentwise-backward-error stopping test (default 4 ulp of
        the value dtype).

        ``plan_cache``: where symbolic plans are looked up / stored —
        ``"default"`` (the process-wide content-addressed cache), a
        :class:`~repro.core.planner.PlanCache`, or ``None`` to always
        rebuild.  ``plan_from_cache`` reports whether construction reused a
        cached plan (and therefore did zero symbolic work).

        ``jit_schedule``/``executable_cache``: the whole-schedule executors —
        one jitted program per (plan digest, executor config), cached
        process-wide so a second GLU on the same plan compiles nothing; a
        (re)factorization or triangular solve is then ONE device dispatch
        (``solve_info["n_dispatches"]`` / ``["solve_dispatches"]``).
        ``fuse_buckets``/``bucket_waste`` control the bucketed ragged level
        fusion feeding those programs.

        ``dense_tail``: switch-to-dense is ON by default — a dense-enough
        trailing column block finishes as one blocked dense-LU group inside
        the fused program instead of hundreds of tiny scatter levels (no-op
        when no qualifying tail exists; ``dense_tail=False`` forces the
        strictly sparse schedule).

        ``layout``: device value-storage layout — ``"auto"`` (default)
        stores complex factors as split re/im planes (planar) whenever
        ``use_pallas=True``, which keeps the Pallas SEGMENTED/PANEL/
        dense-tail kernels in play for complex dtypes (they take no complex
        operands); without ``use_pallas`` auto stays ``"native"``, the
        faster flat-XLA lowering.  ``"native"``/``"planar"`` force either
        path.  The public interface (``solve``, ``factorized_values``,
        refinement) always speaks native complex regardless.

        ``mesh``: a ``jax.sharding.Mesh`` to shard BATCHED factorize/solve
        calls over — the batch (scenario) axis splits along the mesh axes
        the ``"scenario"`` rule of ``repro.distributed.DEFAULT_RULES``
        resolves to (``("pod", "data")``), plan metadata is replicated, and
        each shard runs the whole fused schedule in its single dispatch.
        Batches not divisible by the shard count are padded with copies of
        the last scenario and the pad rows are masked out of results and
        diagnostics.  ``None`` (default) or a mesh resolving to one shard
        runs everything on the default device.  Single-matrix calls are
        never sharded.

        ``verify``: static plan verification (:mod:`repro.analysis`).
        ``"off"`` (default) — none, zero overhead; ``"plan"`` — verify the
        symbolic plan's schedule/index invariants at construction;
        ``"full"`` — additionally walk the built executor and trisolver
        schedules and audit the fused runners' jaxprs.  Violations raise
        :class:`~repro.analysis.PlanVerificationError`; the report summary
        lands in ``solve_info["verify_report"]``.
        """
        plan, scaling, from_cache = plan_factorization(
            A, ordering=ordering, symbolic=symbolic, mc64=mc64,
            panel_threshold=panel_threshold, cache=plan_cache)
        self._setup(
            plan, scaling, A, from_cache=from_cache, dtype=dtype,
            fuse_levels=fuse_levels, fuse_buckets=fuse_buckets,
            bucket_waste=bucket_waste, jit_schedule=jit_schedule,
            executable_cache=executable_cache, use_pallas=use_pallas,
            static_pivot=static_pivot, refine=refine, refine_tol=refine_tol,
            dense_tail=dense_tail, dense_tail_density=dense_tail_density,
            mode_override=mode_override, interpret=interpret, layout=layout,
            mesh=mesh, verify=verify)

    @classmethod
    def from_plan(
        cls,
        plan: SymbolicPlan,
        A: CSC,
        dtype=jnp.float64,
        mc64="scale",
        fuse_levels: bool = True,
        fuse_buckets: bool = True,
        bucket_waste: float = 4.0,
        jit_schedule: bool = True,
        executable_cache="default",
        use_pallas: bool = False,
        static_pivot: Optional[float] = None,
        refine: int = 0,
        refine_tol: Optional[float] = None,
        dense_tail: bool = True,
        dense_tail_density: float = 0.25,
        mode_override: Optional[str] = None,
        interpret: bool = True,
        layout: str = "auto",
        mesh=None,
        verify: str = "off",
    ) -> "GLU":
        """Build a GLU around a prebuilt :class:`SymbolicPlan`, skipping all
        symbolic work.

        ``A`` must carry the exact pattern the plan was built for, and the
        MC64 matching of its values must reproduce ``plan.row_perm`` (for
        ``mc64="scale"`` the matching is recomputed from the new values —
        only the resulting permutation has to agree; the Dr/Dc scalings are
        free to differ).  Raises ``ValueError`` otherwise.
        """
        if not plan.matches_pattern(A):
            raise ValueError("matrix pattern differs from the plan's pattern")
        scaling = compute_scaling(A, mc64)
        if not np.array_equal(scaling.row_perm, plan.row_perm):
            raise ValueError(
                "MC64 matching of these values differs from the plan's "
                "row permutation; rebuild the plan (e.g. GLU(A, ...))")
        self = cls.__new__(cls)
        self._setup(
            plan, scaling, A, from_cache=True, dtype=dtype,
            fuse_levels=fuse_levels, fuse_buckets=fuse_buckets,
            bucket_waste=bucket_waste, jit_schedule=jit_schedule,
            executable_cache=executable_cache, use_pallas=use_pallas,
            static_pivot=static_pivot, refine=refine, refine_tol=refine_tol,
            dense_tail=dense_tail, dense_tail_density=dense_tail_density,
            mode_override=mode_override, interpret=interpret, layout=layout,
            mesh=mesh, verify=verify)
        return self

    def _setup(
        self,
        plan: SymbolicPlan,
        scaling: MC64Scaling,
        A: CSC,
        from_cache: bool,
        dtype,
        fuse_levels: bool,
        fuse_buckets: bool,
        bucket_waste: float,
        jit_schedule: bool,
        executable_cache,
        use_pallas: bool,
        static_pivot: Optional[float],
        refine: int,
        refine_tol: Optional[float],
        dense_tail: bool,
        dense_tail_density: float,
        mode_override: Optional[str],
        interpret: bool,
        layout: str,
        mesh=None,
        verify: str = "off",
    ) -> None:
        # resolve the effective dtype ONCE; a float64/complex128 request
        # without x64 enabled raises here instead of silently degrading
        dtype = resolve_value_dtype(dtype)
        # "auto" picks planar exactly when it buys something: complex dtype
        # AND mode-adaptive Pallas execution requested.  Without use_pallas
        # every level runs flat XLA, where native complex (an interleaved
        # re/im layout already) is the faster lowering — planar would only
        # add plane bookkeeping.  Pass layout="planar" to force planes.
        if layout == "auto" and not use_pallas:
            layout = "native"
        self.layout = resolve_layout(layout, dtype)
        self.n = A.n
        self.symbolic_plan = plan
        self.plan_from_cache = bool(from_cache)
        self._A_scipy = A.to_scipy()
        rows0 = np.asarray(A.indices, dtype=np.int64)
        cols0 = np.repeat(np.arange(A.n, dtype=np.int64), np.diff(A.indptr))
        self.Dr, self.Dc = scaling.Dr, scaling.Dc
        # per-original-entry scale factor: entry (i, j) -> Dr[i] * Dc[j];
        # identity for the unscaled modes, where the multiply is skipped
        self._scale_data = self.Dr[rows0] * self.Dc[cols0]
        self._scale_identity = bool(np.all(self._scale_data == 1.0))
        self.row_map = plan.row_map             # old row -> new row
        self.col_map = plan.col_map             # old col -> new col
        self._inv_row = plan.inv_row
        # original-entry-order -> permuted-entry-order map (for refactorize)
        self._data_perm = plan.data_perm
        # no float64 hard-cast: A.data may be complex (AC analysis); the
        # real Dr/Dc scale factors preserve the value dtype kind
        scaled = np.asarray(A.data) * self._scale_data
        self._A_perm = CSC(A.n, plan.perm_indptr, plan.perm_indices,
                           scaled[self._data_perm])
        # scaled-A SpMV layout (permuted pattern) for iterative refinement
        self._spmv_rows = jnp.asarray(plan.spmv_rows)
        self._spmv_cols = jnp.asarray(plan.spmv_cols)
        self.pattern = plan.pattern
        self.levelization = plan.levelization
        self.plan = plan.fplan
        # scenario sharding: None unless a mesh with >1 scenario shards was
        # given; sharding only ever applies to the batched entry points
        self.mesh = mesh
        self._shard = make_scenario_sharding(mesh)
        self._factorizer = JaxFactorizer(
            self.plan, dtype=dtype, fuse_levels=fuse_levels,
            fuse_buckets=fuse_buckets, bucket_waste=bucket_waste,
            jit_schedule=jit_schedule, executable_cache=executable_cache,
            use_pallas=use_pallas, mode_override=mode_override,
            interpret=interpret, dense_tail=dense_tail,
            dense_tail_density=dense_tail_density, static_pivot=static_pivot,
            layout=self.layout.name, shard=self._shard,
        )
        self._solver = JaxTriangularSolver(
            self.plan, fuse=fuse_levels, fuse_buckets=fuse_buckets,
            bucket_waste=bucket_waste, jit_schedule=jit_schedule,
            executable_cache=executable_cache, layout=self.layout.name,
            shard=self._shard)
        self._vals: Optional[jnp.ndarray] = None
        self._vals_batch: Optional[jnp.ndarray] = None
        self._a_vals: Optional[jnp.ndarray] = None
        self._a_abs: Optional[jnp.ndarray] = None
        self._a_vals_batch: Optional[jnp.ndarray] = None
        self._a_abs_batch: Optional[jnp.ndarray] = None
        # batch geometry of the current batched factorization: the caller's
        # B, the padded total held on device, and their difference
        self._batch_size: Optional[int] = None
        self._batch_total: Optional[int] = None
        self._batch_pad: int = 0
        self.dtype = dtype
        self.refine_default = int(refine)
        self.refine_tol = (float(refine_tol) if refine_tol is not None
                           else 4.0 * float(jnp.finfo(dtype).eps))
        self._info: Optional[dict] = None
        self._pending_stats = None
        if verify not in ("off", "plan", "full"):
            raise ValueError(
                f"verify must be 'off', 'plan' or 'full', got {verify!r}")
        self.verify = verify
        self.verify_report = None
        if verify != "off":
            # lazy import: analysis depends on core, not the other way round
            from ..analysis import verify_glu

            self.verify_report = verify_glu(self, verify)
            self.verify_report.raise_if_violated()

    # -- numeric phase (repeatable) -----------------------------------------
    def factorize(self, a_data=None) -> "GLU":
        """(Re)factorize; ``a_data`` are new values in A's original CSC entry
        order (same pattern — the SPICE refactorization contract).  The
        batched factor cache is invalidated: the two caches can never refer
        to different matrix values."""
        if a_data is None:
            data = np.asarray(self._A_perm.data)
        elif self._scale_identity:
            data = np.asarray(a_data)[self._data_perm]
        else:
            data = (np.asarray(a_data) * self._scale_data)[self._data_perm]
        self._a_vals = jnp.asarray(data, dtype=self.dtype)
        self._a_abs = None                     # lazily built on refined solve
        self._vals = self._factorizer.factorize(self._a_vals)
        self._vals_batch = None
        self._a_vals_batch = None
        self._a_abs_batch = None
        self._batch_size = self._batch_total = None
        self._batch_pad = 0
        self._set_fact_info(self._vals, self._a_vals, batched=False)
        return self

    def factorized_values(self) -> jnp.ndarray:
        """Factored (nnz,) values in the plan's filled pattern — always in
        the NATIVE value dtype (planar plane storage is unpacked here; use
        ``_vals`` for the raw device layout)."""
        if self._vals is None:
            raise RuntimeError("call factorize() first")
        if self.layout.planar:
            return unpack_planes(self._vals)
        return self._vals

    def _map_rhs_pattern(self, rhs_pattern, b) -> Optional[np.ndarray]:
        """Translate a rhs nonzero pattern from ORIGINAL row indices to the
        solver's permuted positions, validating that ``b`` really is zero
        outside the pattern (a nonzero outside it would be silently
        dropped by the pruned schedule)."""
        if rhs_pattern is None:
            return None
        pat = np.unique(np.asarray(rhs_pattern, dtype=np.int64).ravel())
        if pat.size and (pat[0] < 0 or pat[-1] >= self.n):
            raise ValueError(f"rhs_pattern indices out of range [0, {self.n})")
        mask = np.zeros(self.n, dtype=bool)
        mask[pat] = True
        bad = np.asarray(b) != 0
        if bad.ndim == 2:
            bad = bad.any(axis=0)
        if np.any(bad & ~mask):
            raise ValueError(
                "rhs has nonzero entries outside rhs_pattern; the pruned "
                "solve would silently drop them")
        return self.row_map[pat]

    def solve(self, b, refine: Optional[int] = None,
              rhs_pattern=None) -> np.ndarray:
        """Solve A x = b using the current factorization; ``refine`` extra
        iterative-refinement sweeps reuse the device factors (default: the
        constructor's ``refine``).  ``rhs_pattern`` — indices (original row
        numbering) of b's nonzero support — prunes the triangular-solve
        schedule to the reach closure of the pattern (raises if b is
        nonzero outside it)."""
        if self._vals is None:
            if self._vals_batch is not None:
                raise RuntimeError(
                    "the active factorization is batched — use solve_batched(),"
                    " or call factorize() to refactorize single-matrix first")
            self.factorize()
        k = self.refine_default if refine is None else int(refine)
        pat = self._map_rhs_pattern(rhs_pattern, b)
        bp = (np.asarray(b) * self.Dr)[self._inv_row]
        if k > 0:
            if self._a_abs is None:
                self._a_abs = jnp.abs(self._a_vals)
            xp, rinfo = self._solver.solve_refined(
                self._vals, bp, self._spmv_rows, self._spmv_cols,
                self._a_vals, self._a_abs, max_iter=k, tol=self.refine_tol,
                rhs_pattern=pat)
            xp = np.asarray(xp)
        else:
            xp = np.asarray(self._solver.solve(self._vals, bp,
                                               rhs_pattern=pat))
            rinfo = {"refine_iters": 0, "backward_error": None,
                     "converged": None, "host_syncs": 0}
        self._set_solve_info(rinfo)
        return xp[self.col_map] * self.Dc

    def solve_multi(self, b_multi, refine: Optional[int] = None,
                    rhs_pattern=None) -> np.ndarray:
        """Solve A X^T = B^T — many right-hand sides against the CURRENT
        single-matrix factorization (the adjoint/sensitivity workload:
        K seed vectors, one Jacobian).  ``b_multi`` is (K, n), returns
        (K, n); each level group is one device dispatch for all K rhs.
        ``rhs_pattern`` is the union support of all rows."""
        if self._vals is None:
            if self._vals_batch is not None:
                raise RuntimeError(
                    "the active factorization is batched — use solve_batched(),"
                    " or call factorize() to refactorize single-matrix first")
            self.factorize()
        b = np.asarray(b_multi)
        if b.ndim != 2 or b.shape[1] != self.n:
            raise ValueError(f"expected (K, {self.n}) rhs, got {b.shape}")
        k = self.refine_default if refine is None else int(refine)
        pat = self._map_rhs_pattern(rhs_pattern, b)
        bp = (b * self.Dr[None, :])[:, self._inv_row]
        if k > 0:
            if self._a_abs is None:
                self._a_abs = jnp.abs(self._a_vals)
            xp, rinfo = self._solver.solve_refined_multi(
                self._vals, bp, self._spmv_rows, self._spmv_cols,
                self._a_vals, self._a_abs, max_iter=k, tol=self.refine_tol,
                rhs_pattern=pat)
            xp = np.asarray(xp)
        else:
            xp = np.asarray(self._solver.solve_multi(self._vals, bp,
                                                     rhs_pattern=pat))
            rinfo = {"refine_iters": np.zeros(b.shape[0], dtype=np.int64),
                     "backward_error": None, "converged": None,
                     "host_syncs": 0}
        self._set_solve_info(rinfo)
        return xp[:, self.col_map] * self.Dc[None, :]

    # -- batched numeric phase (one plan, many matrices) ----------------------
    def factorize_batched(self, a_data_batch) -> "GLU":
        """Factorize B matrices on this pattern in lockstep.

        ``a_data_batch``: (B, nnz) values, one matrix per row, each in A's
        original CSC entry order (the Monte-Carlo / parameter-sweep
        refactorization contract: one symbolic plan, many value vectors).
        The single-matrix factor cache is invalidated."""
        data = np.asarray(a_data_batch)
        if data.ndim != 2:
            raise ValueError(f"expected (B, nnz) values, got shape {data.shape}")
        if self._scale_identity:
            scaled = data[:, self._data_perm]
        else:
            scaled = (data * self._scale_data[None, :])[:, self._data_perm]
        B = scaled.shape[0]
        self._batch_size = self._batch_total = B
        self._batch_pad = 0
        if self._shard is not None and B > 1:
            # non-divisible batches are padded with copies of the LAST
            # scenario (a known-factorizable system, so the pad rows can
            # never poison diagnostics with inf/NaN) and masked out of
            # results and convergence below — the scenario-axis analogue of
            # the silent-replicate rule in distributed/sharding.py.  B == 1
            # stays unsharded: padding a single matrix across the mesh buys
            # nothing.
            total = self._shard.pad(B)
            if total != B:
                scaled = np.concatenate(
                    [scaled, np.repeat(scaled[-1:], total - B, axis=0)])
            self._batch_total = total
            self._batch_pad = total - B
        self._a_vals_batch = jnp.asarray(scaled, dtype=self.dtype)
        if self._shard is not None and self._batch_total % self._shard.n_shards == 0:
            # place the batch sharded BEFORE dispatch so the runner never
            # reshuffles it (donation-safe: the runner does not donate it)
            self._a_vals_batch = self._shard.shard_batch(self._a_vals_batch)
        self._a_abs_batch = None               # lazily built on refined solve
        self._vals_batch = self._factorizer.factorize_batched(self._a_vals_batch)
        self._vals = None
        self._a_vals = None
        self._a_abs = None
        self._set_fact_info(self._vals_batch, self._a_vals_batch, batched=True)
        return self

    def factorized_values_batched(self) -> jnp.ndarray:
        if self._vals_batch is None:
            raise RuntimeError("call factorize_batched() first")
        vals = self._vals_batch
        if self._batch_pad:
            vals = vals[: self._batch_size]
        if self.layout.planar:
            return unpack_planes(vals)
        return vals

    def solve_batched(self, b_batch, refine: Optional[int] = None,
                      rhs_pattern=None) -> np.ndarray:
        """Solve A_i x_i = b_i for every matrix of the current batched
        factorization; ``b_batch`` is (B, n), returns (B, n).  A
        ``rhs_pattern`` is shared by the batch (union support)."""
        if self._vals_batch is None:
            raise RuntimeError("call factorize_batched() first")
        B = np.asarray(b_batch).shape[0]
        if self._batch_size is not None and B != self._batch_size:
            raise ValueError(
                f"rhs batch of {B} does not match the factorized batch of "
                f"{self._batch_size}")
        k = self.refine_default if refine is None else int(refine)
        pat = self._map_rhs_pattern(rhs_pattern, np.asarray(b_batch))
        bp = (np.asarray(b_batch) * self.Dr[None, :])[:, self._inv_row]
        if self._batch_pad:
            # zero rhs rows for the pad scenarios: their solution is exactly
            # zero (and their backward error 0/0 counts as converged), so
            # refinement never iterates for them
            bp = np.concatenate(
                [bp, np.zeros((self._batch_pad, bp.shape[1]), dtype=bp.dtype)])
        bpd = jnp.asarray(bp)
        if (self._shard is not None
                and bpd.shape[0] % self._shard.n_shards == 0):
            bpd = self._shard.shard_batch(bpd)
        if k > 0:
            if self._a_abs_batch is None:
                self._a_abs_batch = jnp.abs(self._a_vals_batch)
            xp, rinfo = self._solver.solve_refined_batched(
                self._vals_batch, bpd, self._spmv_rows, self._spmv_cols,
                self._a_vals_batch, self._a_abs_batch,
                max_iter=k, tol=self.refine_tol, rhs_pattern=pat)
            xp = np.asarray(xp)
            if self._batch_pad:
                rinfo = {key: (v[:B] if isinstance(v, np.ndarray) else v)
                         for key, v in rinfo.items()}
        else:
            xp = np.asarray(self._solver.solve_batched(self._vals_batch, bpd,
                                                       rhs_pattern=pat))
            rinfo = {"refine_iters": np.zeros(B, dtype=np.int64),
                     "backward_error": None, "converged": None,
                     "host_syncs": 0}
        if self._batch_pad:
            xp = xp[:B]
        self._set_solve_info(rinfo)
        return xp[:, self.col_map] * self.Dc[None, :]

    def refactorize_solve(self, a_data_batch, b_batch,
                          refine: Optional[int] = None,
                          rhs_pattern=None) -> np.ndarray:
        """Fused batched refactorize + solve in one call (the Newton inner
        step of a parameter sweep).  Accepts (B, nnz)+(B, n) or a single
        (nnz,)+(n,) pair; the factored values stay on device between the
        two phases and are kept for later ``solve_batched`` calls."""
        data = np.asarray(a_data_batch)
        b = np.asarray(b_batch)
        single = data.ndim == 1
        if single:
            data, b = data[None], b[None]
        self.factorize_batched(data)
        x = self.solve_batched(b, refine=refine, rhs_pattern=rhs_pattern)
        if single:
            self._vals = self._vals_batch[0]
            self._a_vals = self._a_vals_batch[0]
            self._a_abs = (None if self._a_abs_batch is None
                           else self._a_abs_batch[0])
            # collapse diagnostics to the documented single-matrix contract
            # (scalars, batched=False), matching the returned x[0]
            if self._pending_stats is not None:
                _, _, a_max, n_pert, _ = self._pending_stats
                self._pending_stats = (
                    self._vals, self._a_vals,
                    None if a_max is None else a_max[0],
                    None if n_pert is None else n_pert[0], False)
            if self._info is not None:
                self._info["batched"] = False
                for key in ("pivot_growth", "min_diag", "n_perturbed",
                            "refine_iters", "backward_error", "converged"):
                    v = self._info.get(key)
                    if v is not None and not isinstance(v, (bool, int, float)):
                        self._info[key] = np.asarray(v)[0]
            return x[0]
        return x

    # -- diagnostics ----------------------------------------------------------
    def _set_fact_info(self, factored_vals, a_vals, batched: bool) -> None:
        """Record which factorization the next ``solve_info`` describes.
        The growth/min-diag reductions (and max|A| when the static-pivot
        guard didn't already need it) are deferred to first ``solve_info``
        access so the hot refactorization path pays nothing for them."""
        self._pending_stats = (factored_vals, a_vals,
                               self._factorizer.last_a_max,
                               self._factorizer.last_n_perturbed,
                               batched)
        sharded = (batched and self._shard is not None
                   and self._batch_total is not None
                   and self._batch_total % self._shard.n_shards == 0)
        self._info = {
            "batched": batched,
            "pivot_growth": None,
            "min_diag": None,
            "n_perturbed": None,
            "refine_iters": None,
            "backward_error": None,
            "converged": None,
            # executor shape: how many schedule groups the plan compiled to
            # and how many device dispatches this factorization actually
            # issued (1 on the fused whole-schedule path)
            "n_groups": self._factorizer.n_groups,
            "n_dispatches": self._factorizer.last_n_dispatches,
            "solve_dispatches": None,
            # mode-adaptive execution surface: which storage layout the
            # factors use, and — when any Pallas-eligible work was routed
            # off the Pallas path — why (None means fully active)
            "layout": self.layout.name,
            "pallas_disabled_reason": self._factorizer.pallas_disabled_reason,
            # scenario-sharding surface: how many devices the batch axis
            # split over (1 = unsharded) and the PartitionSpec it used.
            # ``n_perturbed_global`` is the cross-shard exact psum of
            # static-pivot bumps over the PADDED batch (pad rows duplicate
            # the last scenario, so their bumps are counted again); None
            # unless the guard ran sharded.
            "n_devices": self._shard.n_shards if sharded else 1,
            "batch_spec": str(self._shard.spec) if sharded else None,
            "n_perturbed_global": self._factorizer.last_n_perturbed_global,
            # static-verification digest (None when verify="off")
            "verify_report": (None if self.verify_report is None
                              else self.verify_report.summary()),
        }

    def _set_solve_info(self, rinfo: dict) -> None:
        if self._info is None:
            self._info = {"batched": False, "pivot_growth": None,
                          "min_diag": None, "n_perturbed": None,
                          "n_groups": self._factorizer.n_groups,
                          "n_dispatches": None,
                          "layout": self.layout.name,
                          "pallas_disabled_reason":
                              self._factorizer.pallas_disabled_reason,
                          "n_devices": 1, "batch_spec": None,
                          "n_perturbed_global": None,
                          "verify_report": (
                              None if self.verify_report is None
                              else self.verify_report.summary())}
        self._info.update(rinfo)
        self._info["solve_dispatches"] = self._solver.last_n_dispatches

    @property
    def refine_converged(self):
        """Convergence flag (and nothing else) of the latest refined solve:
        scalar bool / (B,) bool array, or None when the last solve ran
        unrefined.  Unlike ``solve_info`` it does not force the deferred
        pivot-growth/min-diag device reductions, so the Newton hot loop can
        poll it every iterate for free."""
        if self._info is None:
            return None
        v = self._info.get("converged")
        if v is None or isinstance(v, bool):
            return v
        a = np.asarray(v)
        return bool(a.item()) if a.ndim == 0 else a

    @property
    def solve_info(self) -> Optional[dict]:
        """Robustness report of the latest factorize/solve: ``pivot_growth``
        (max|LU|/max|A|), ``min_diag``, ``n_perturbed`` (static-pivot bumps;
        None when the guard is off), ``refine_iters``, ``backward_error``
        (componentwise), ``converged``, and ``batched``.  Scalars for the
        single-matrix path, (B,) arrays for the batched one."""
        if self._info is None:
            return None
        if self._pending_stats is not None:
            from ..kernels import ops as kops

            vals, a_vals, a_max, n_pert, batched = self._pending_stats
            if a_max is None:
                a_abs = jnp.abs(a_vals)
                a_max = jnp.max(a_abs, axis=1) if batched else jnp.max(a_abs)
            if self.layout.planar:
                fn = (kops.factor_stats_planar_batched if batched
                      else kops.factor_stats_planar)
            else:
                fn = (kops.factor_stats_batched if batched
                      else kops.factor_stats)
            growth, min_diag = fn(vals, self._factorizer._diag_idx, a_max)
            if batched and self._batch_pad:
                # drop the pad scenarios from the per-matrix diagnostics
                growth = growth[: self._batch_size]
                min_diag = min_diag[: self._batch_size]
                if n_pert is not None:
                    n_pert = n_pert[: self._batch_size]
            self._info.update(pivot_growth=growth, min_diag=min_diag,
                              n_perturbed=n_pert)
            self._pending_stats = None
        out = {}
        for key, v in self._info.items():
            if v is None or isinstance(v, (bool, int, float, str, dict)):
                out[key] = v
            else:
                a = np.asarray(v)
                out[key] = a.item() if a.ndim == 0 else a
        return out

    @property
    def n_devices(self) -> int:
        """Shard count batched calls split over (1 = unsharded)."""
        return 1 if self._shard is None else self._shard.n_shards

    @property
    def nnz_filled(self) -> int:
        return self.pattern.nnz

    @property
    def num_levels(self) -> int:
        return self.levelization.num_levels

    def residual(self, b, x) -> float:
        """||Ax - b||_inf / ||b||_inf on the original system."""
        r = self._A_scipy @ np.asarray(x) - np.asarray(b)
        return float(np.abs(r).max() / (np.abs(b).max() + 1e-300))
