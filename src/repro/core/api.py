"""GLU facade: the paper's full flow (Fig. 5) behind one class.

  A -> MC64-lite (zero-free diagonal) -> fill-reducing ordering ->
  symbolic fill-in -> relaxed dependency detection + levelization ->
  plan -> (re)factorize on device -> triangular solve

Construction does all host-side symbolic work once; ``factorize``/``solve``
are the fast repeated path (SPICE Newton iterations reuse the plan).

Permutation algebra: with row_map/col_map (old -> new),
``A_perm[row_map[i], col_map[j]] = A[i, j]`` and solving ``A x = b`` becomes
``A_perm x_perm = b_perm`` with ``b_perm = b[inv_row_map]`` and
``x = x_perm[col_map]``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax.numpy as jnp

from ..sparse.csc import CSC
from .dependency import levelize_relaxed
from .factorize import JaxFactorizer
from .ordering import fill_reducing_ordering, zero_free_diagonal
from .plan import build_plan
from .symbolic import symbolic_fillin
from .triangular import JaxTriangularSolver

__all__ = ["GLU"]


class GLU:
    def __init__(
        self,
        A: CSC,
        ordering: str = "auto",
        symbolic: str = "auto",
        dtype=jnp.float64,
        mc64: bool = True,
        fuse_levels: bool = True,
        use_pallas: bool = False,
        panel_threshold: int = 16,
    ):
        self.n = A.n
        self._A_scipy = A.to_scipy()
        # --- preprocessing -------------------------------------------------
        if mc64:
            row_perm = zero_free_diagonal(A)
        else:
            row_perm = np.arange(A.n, dtype=np.int64)
        A_rp = A.permute(row_perm, np.arange(A.n, dtype=np.int64))
        sym_perm = fill_reducing_ordering(A_rp, ordering)
        self.row_map = sym_perm[row_perm]       # old row -> new row
        self.col_map = sym_perm                 # old col -> new col
        self._inv_row = np.argsort(self.row_map)
        A_perm = A.permute(self.row_map, self.col_map)
        self._A_perm = A_perm
        # original-entry-order -> permuted-entry-order map (for refactorize)
        rows0, cols0, _ = A.to_coo()
        self._data_perm = np.lexsort((self.row_map[rows0], self.col_map[cols0]))

        # --- symbolic ------------------------------------------------------
        self.pattern = symbolic_fillin(A_perm, symbolic)
        self.levelization = levelize_relaxed(self.pattern)
        self.plan = build_plan(self.pattern, self.levelization,
                               panel_threshold=panel_threshold)
        self._factorizer = JaxFactorizer(
            self.plan, dtype=dtype, fuse_levels=fuse_levels, use_pallas=use_pallas
        )
        self._solver = JaxTriangularSolver(self.plan)
        self._vals: Optional[jnp.ndarray] = None
        self._vals_batch: Optional[jnp.ndarray] = None
        self.dtype = dtype

    # -- numeric phase (repeatable) -----------------------------------------
    def factorize(self, a_data=None) -> "GLU":
        """(Re)factorize; ``a_data`` are new values in A's original CSC entry
        order (same pattern — the SPICE refactorization contract)."""
        if a_data is None:
            data = np.asarray(self._A_perm.data)
        else:
            data = np.asarray(a_data)[self._data_perm]
        self._vals = self._factorizer.factorize(data)
        return self

    def factorized_values(self) -> jnp.ndarray:
        if self._vals is None:
            raise RuntimeError("call factorize() first")
        return self._vals

    def solve(self, b) -> np.ndarray:
        """Solve A x = b using the current factorization."""
        if self._vals is None:
            self.factorize()
        bp = np.asarray(b, dtype=np.float64)[self._inv_row]
        xp = np.asarray(self._solver.solve(self._vals, bp))
        return xp[self.col_map]

    # -- batched numeric phase (one plan, many matrices) ----------------------
    def factorize_batched(self, a_data_batch) -> "GLU":
        """Factorize B matrices on this pattern in lockstep.

        ``a_data_batch``: (B, nnz) values, one matrix per row, each in A's
        original CSC entry order (the Monte-Carlo / parameter-sweep
        refactorization contract: one symbolic plan, many value vectors).
        """
        data = np.asarray(a_data_batch)
        if data.ndim != 2:
            raise ValueError(f"expected (B, nnz) values, got shape {data.shape}")
        self._vals_batch = self._factorizer.factorize_batched(
            data[:, self._data_perm])
        return self

    def factorized_values_batched(self) -> jnp.ndarray:
        if self._vals_batch is None:
            raise RuntimeError("call factorize_batched() first")
        return self._vals_batch

    def solve_batched(self, b_batch) -> np.ndarray:
        """Solve A_i x_i = b_i for every matrix of the current batched
        factorization; ``b_batch`` is (B, n), returns (B, n)."""
        if self._vals_batch is None:
            raise RuntimeError("call factorize_batched() first")
        bp = np.asarray(b_batch, dtype=np.float64)[:, self._inv_row]
        xp = np.asarray(self._solver.solve_batched(self._vals_batch, bp))
        return xp[:, self.col_map]

    def refactorize_solve(self, a_data_batch, b_batch) -> np.ndarray:
        """Fused batched refactorize + solve in one call (the Newton inner
        step of a parameter sweep).  Accepts (B, nnz)+(B, n) or a single
        (nnz,)+(n,) pair; the factored values stay on device between the
        two phases and are kept for later ``solve_batched`` calls."""
        data = np.asarray(a_data_batch)
        b = np.asarray(b_batch)
        single = data.ndim == 1
        if single:
            data, b = data[None], b[None]
        self.factorize_batched(data)
        x = self.solve_batched(b)
        if single:
            self._vals = self._vals_batch[0]
            return x[0]
        return x

    # -- diagnostics ----------------------------------------------------------
    @property
    def nnz_filled(self) -> int:
        return self.pattern.nnz

    @property
    def num_levels(self) -> int:
        return self.levelization.num_levels

    def residual(self, b, x) -> float:
        """||Ax - b||_inf / ||b||_inf on the original system."""
        r = self._A_scipy @ np.asarray(x, dtype=np.float64) - np.asarray(b)
        return float(np.abs(r).max() / (np.abs(b).max() + 1e-300))
