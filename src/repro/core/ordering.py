"""Preprocessing: zero-free diagonal permutation (MC64-lite) and fill-reducing
ordering (minimum-degree / RCM).

The GLU flow (paper Fig. 5) runs MC64 + AMD before symbolic analysis.  Here:

* ``zero_free_diagonal`` — maximum-cardinality bipartite matching (the
  structural half of MC64; the max-product scaling variant is out of scope,
  see DESIGN.md assumption log).
* ``minimum_degree`` — classic minimum-degree on the symmetrised pattern
  (pure python; fine to ~20k columns on this host).
* ``rcm`` — reverse Cuthill-McKee via scipy (fast C path for large n).
* ``fill_reducing_ordering`` — dispatcher used by the GLU facade.

All orderings return ``perm`` with the convention new = perm[old]
(i.e. ``A.permute(perm, perm)`` applies it).
"""
from __future__ import annotations

import heapq

import numpy as np

from ..sparse.csc import CSC

__all__ = [
    "zero_free_diagonal",
    "minimum_degree",
    "rcm",
    "fill_reducing_ordering",
]


def zero_free_diagonal(A: CSC) -> np.ndarray:
    """Row permutation (old row -> new row) giving a structurally zero-free diagonal.

    Uses scipy's Hopcroft-Karp maximum bipartite matching on the pattern.
    Raises if the matrix is structurally singular.
    """
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    S = sp.csc_matrix(
        (np.ones(A.nnz, dtype=np.int8), A.indices, A.indptr), shape=(A.n, A.n)
    )
    # match[col] = row assigned to column col
    match = maximum_bipartite_matching(S.tocsr(), perm_type="row")
    if (match < 0).any():
        raise ValueError("matrix is structurally singular (no perfect matching)")
    # we need row old->new such that new_row(match[j]) == j
    perm = np.empty(A.n, dtype=np.int64)
    perm[match] = np.arange(A.n)
    return perm


def _sym_adjacency(A: CSC):
    """Symmetrised adjacency lists (no self loops) as a list of sets."""
    adj = [set() for _ in range(A.n)]
    cols = np.repeat(np.arange(A.n), np.diff(A.indptr))
    for r, c in zip(A.indices, cols):
        if r != c:
            adj[r].add(int(c))
            adj[c].add(int(r))
    return adj


def minimum_degree(A: CSC) -> np.ndarray:
    """Minimum-degree ordering on the symmetrised pattern (old -> new)."""
    n = A.n
    adj = _sym_adjacency(A)
    heap = [(len(adj[v]), v) for v in range(n)]
    heapq.heapify(heap)
    eliminated = np.zeros(n, dtype=bool)
    order = []
    stamp = np.full(n, -1, dtype=np.int64)
    while heap:
        d, v = heapq.heappop(heap)
        if eliminated[v] or d != len(adj[v]):
            continue  # stale entry
        eliminated[v] = True
        order.append(v)
        nbrs = [u for u in adj[v] if not eliminated[u]]
        # clique the neighbourhood (elimination graph update)
        for u in nbrs:
            adj[u].discard(v)
        for i, u in enumerate(nbrs):
            au = adj[u]
            for w in nbrs[i + 1 :]:
                if w not in au:
                    au.add(w)
                    adj[w].add(u)
        for u in nbrs:
            if stamp[u] != len(adj[u]):
                stamp[u] = len(adj[u])
                heapq.heappush(heap, (len(adj[u]), u))
        adj[v] = set()
    perm = np.empty(n, dtype=np.int64)
    perm[np.array(order)] = np.arange(n)
    return perm


def rcm(A: CSC) -> np.ndarray:
    """Reverse Cuthill-McKee on the symmetrised pattern (old -> new)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    S = sp.csc_matrix(
        (np.ones(A.nnz, dtype=np.int8), A.indices, A.indptr), shape=(A.n, A.n)
    )
    S = (S + S.T).tocsr()
    order = reverse_cuthill_mckee(S, symmetric_mode=True)
    perm = np.empty(A.n, dtype=np.int64)
    perm[order] = np.arange(A.n)
    return perm


def fill_reducing_ordering(A: CSC, method: str = "auto") -> np.ndarray:
    if method == "none":
        return np.arange(A.n, dtype=np.int64)
    if method == "auto":
        method = "mindeg" if A.n <= 6000 else "rcm"
    if method == "mindeg":
        return minimum_degree(A)
    if method == "rcm":
        return rcm(A)
    raise ValueError(f"unknown ordering method {method!r}")
