"""Preprocessing: MC64 matching/scaling and fill-reducing ordering
(minimum-degree / RCM).

The GLU flow (paper Fig. 5) runs MC64 + AMD before symbolic analysis.  Here:

* ``zero_free_diagonal`` — maximum-cardinality bipartite matching (the
  structural half of MC64 only).
* ``max_product_matching`` — the full Duff-Koster MC64 (job 5): a row
  permutation maximising the product of diagonal magnitudes, plus the dual
  row/column scalings ``Dr``/``Dc`` that make every scaled entry <= 1 in
  magnitude with exact 1s on the matched (diagonal) positions.  This is the
  numerical half that pivoting-free LU relies on.
* ``minimum_degree`` — classic minimum-degree on the symmetrised pattern
  (pure python; fine to ~20k columns on this host).
* ``rcm`` — reverse Cuthill-McKee via scipy (fast C path for large n).
* ``fill_reducing_ordering`` — dispatcher used by the GLU facade.

All orderings return ``perm`` with the convention new = perm[old]
(i.e. ``A.permute(perm, perm)`` applies it).
"""
from __future__ import annotations

import heapq

import numpy as np

from ..sparse.csc import CSC

__all__ = [
    "zero_free_diagonal",
    "max_product_matching",
    "minimum_degree",
    "rcm",
    "fill_reducing_ordering",
    "resolve_ordering_method",
]


def zero_free_diagonal(A: CSC) -> np.ndarray:
    """Row permutation (old row -> new row) giving a structurally zero-free diagonal.

    Uses scipy's Hopcroft-Karp maximum bipartite matching on the pattern.
    Raises if the matrix is structurally singular.
    """
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    S = sp.csc_matrix(
        (np.ones(A.nnz, dtype=np.int8), A.indices, A.indptr), shape=(A.n, A.n)
    )
    # match[col] = row assigned to column col
    match = maximum_bipartite_matching(S.tocsr(), perm_type="row")
    if (match < 0).any():
        raise ValueError("matrix is structurally singular (no perfect matching)")
    # we need row old->new such that new_row(match[j]) == j
    perm = np.empty(A.n, dtype=np.int64)
    perm[match] = np.arange(A.n)
    return perm


def max_product_matching(A: CSC):
    """Duff-Koster MC64 max-product matching with dual scalings.

    Finds the row permutation maximising ``prod_j |A[match(j), j]|`` by
    solving the equivalent linear assignment problem with costs
    ``c[i,j] = log(colmax_j) - log|a[i,j]|`` (sparse successive shortest
    augmenting paths with dual potentials ``u`` on rows, ``v`` on columns).

    Returns ``(row_perm, Dr, Dc)`` where ``row_perm`` is old row -> new row
    (matched entries land on the diagonal) and the scalings satisfy
    ``|Dr[i] * A[i, j] * Dc[j]| <= 1`` for every stored entry, with equality
    on the matched ones.  Raises on structurally or numerically singular
    input (a perfect matching over the nonzero values must exist).

    Cost: the cheap pass (a column's max entry has zero cost) matches every
    column of a diagonally dominant matrix in O(nnz); only columns it
    leaves unmatched pay the pure-python Dijkstra, O(nnz log n) each, so
    badly-matched large instances can be slow — ``GLU(mc64="structural")``
    keeps the scipy C matching for those.
    """
    n = A.n
    indptr, indices = A.indptr, A.indices
    # Duff-Koster is defined on entry magnitudes: take |a_ij| BEFORE any
    # dtype cast, so complex matrices (AC analysis) match on |G + jwC|
    absval = np.abs(np.asarray(A.data)).astype(np.float64)
    colmax = np.zeros(n)
    np.maximum.at(colmax, np.repeat(np.arange(n), np.diff(indptr)), absval)
    if (colmax == 0).any():
        raise ValueError("numerically singular: column of exact zeros")
    cols_of = np.repeat(np.arange(n), np.diff(indptr))
    with np.errstate(divide="ignore"):
        cost = np.log(colmax[cols_of]) - np.log(absval)  # inf on zero entries

    u = np.zeros(n)                      # row duals
    v = np.zeros(n)                      # column duals
    row_to_col = np.full(n, -1, dtype=np.int64)
    col_to_row = np.full(n, -1, dtype=np.int64)

    # cheap pass: a column's max entry has cost 0, so matching it keeps the
    # zero duals feasible and tight
    for j in range(n):
        s, e = int(indptr[j]), int(indptr[j + 1])
        for p in range(s, e):
            if cost[p] == 0.0 and row_to_col[indices[p]] == -1:
                row_to_col[indices[p]] = j
                col_to_row[j] = int(indices[p])
                break

    inf = np.inf
    for j0 in np.flatnonzero(col_to_row == -1):
        # Dijkstra over rows with reduced costs c[i,j] - u[i] - v[j] >= 0
        dist = np.full(n, inf)
        prev_col = np.full(n, -1, dtype=np.int64)
        done = np.zeros(n, dtype=bool)
        heap: list = []
        j = int(j0)
        base = 0.0                       # distance to the current column
        sink = -1
        while True:
            s, e = int(indptr[j]), int(indptr[j + 1])
            for p in range(s, e):
                i = int(indices[p])
                if done[i] or cost[p] == inf:
                    continue
                nd = base + cost[p] - u[i] - v[j]
                if nd < dist[i]:
                    dist[i] = nd
                    prev_col[i] = j
                    heapq.heappush(heap, (nd, i))
            while heap:
                d_i, i = heapq.heappop(heap)
                if not done[i]:
                    break
            else:
                raise ValueError(
                    "no perfect matching over nonzero values "
                    "(matrix is structurally or numerically singular)")
            done[i] = True
            base = d_i
            if row_to_col[i] == -1:
                sink = i
                break
            j = int(row_to_col[i])
        delta = base
        # dual update keeps feasibility and makes the augmenting path tight
        fin = np.flatnonzero(done)
        u[fin] += dist[fin] - delta
        matched = fin[row_to_col[fin] >= 0]
        v[row_to_col[matched]] += delta - dist[matched]
        v[j0] += delta
        # augment along the stored predecessor columns
        i = sink
        while i != -1:
            j = int(prev_col[i])
            nxt = int(col_to_row[j])
            row_to_col[i] = j
            col_to_row[j] = i
            i = nxt

    # matched entries: u[i] + v[j] = log(colmax_j) - log|a_ij|
    #   => exp(u[i]) * |a_ij| * exp(v[j]) / colmax_j = 1
    Dr = np.exp(u)
    Dc = np.exp(v) / colmax
    row_perm = row_to_col.copy()
    return row_perm, Dr, Dc


def _sym_adjacency(A: CSC):
    """Symmetrised adjacency lists (no self loops) as a list of sets."""
    adj = [set() for _ in range(A.n)]
    cols = np.repeat(np.arange(A.n), np.diff(A.indptr))
    for r, c in zip(A.indices, cols):
        if r != c:
            adj[r].add(int(c))
            adj[c].add(int(r))
    return adj


def minimum_degree(A: CSC) -> np.ndarray:
    """Minimum-degree ordering on the symmetrised pattern (old -> new)."""
    n = A.n
    adj = _sym_adjacency(A)
    heap = [(len(adj[v]), v) for v in range(n)]
    heapq.heapify(heap)
    eliminated = np.zeros(n, dtype=bool)
    order = []
    stamp = np.full(n, -1, dtype=np.int64)
    while heap:
        d, v = heapq.heappop(heap)
        if eliminated[v] or d != len(adj[v]):
            continue  # stale entry
        eliminated[v] = True
        order.append(v)
        nbrs = [u for u in adj[v] if not eliminated[u]]
        # clique the neighbourhood (elimination graph update)
        for u in nbrs:
            adj[u].discard(v)
        for i, u in enumerate(nbrs):
            au = adj[u]
            for w in nbrs[i + 1 :]:
                if w not in au:
                    au.add(w)
                    adj[w].add(u)
        for u in nbrs:
            if stamp[u] != len(adj[u]):
                stamp[u] = len(adj[u])
                heapq.heappush(heap, (len(adj[u]), u))
        adj[v] = set()
    perm = np.empty(n, dtype=np.int64)
    perm[np.array(order)] = np.arange(n)
    return perm


def rcm(A: CSC) -> np.ndarray:
    """Reverse Cuthill-McKee on the symmetrised pattern (old -> new)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    S = sp.csc_matrix(
        (np.ones(A.nnz, dtype=np.int8), A.indices, A.indptr), shape=(A.n, A.n)
    )
    S = (S + S.T).tocsr()
    order = reverse_cuthill_mckee(S, symmetric_mode=True)
    perm = np.empty(A.n, dtype=np.int64)
    perm[order] = np.arange(A.n)
    return perm


def resolve_ordering_method(n: int, method: str = "auto") -> str:
    """Resolve ``"auto"`` to the concrete ordering used for an n-column matrix
    (part of the plan-cache key contract: keys are stored under resolved
    method names so ``"auto"`` and its resolution share one plan)."""
    if method == "auto":
        return "mindeg" if n <= 6000 else "rcm"
    if method in ("none", "mindeg", "rcm"):
        return method
    raise ValueError(f"unknown ordering method {method!r}")


def fill_reducing_ordering(A: CSC, method: str = "auto") -> np.ndarray:
    method = resolve_ordering_method(A.n, method)
    if method == "none":
        return np.arange(A.n, dtype=np.int64)
    if method == "mindeg":
        return minimum_degree(A)
    return rcm(A)
