from .checkpoint import Checkpointer, latest_step, restore_checkpoint, save_checkpoint
from .fault import PreemptionGuard, StepWatchdog
from .optimizer import OptConfig, apply_updates, cosine_lr, init_opt_state
from .train_step import TrainConfig, loss_fn, make_train_step

__all__ = [
    "Checkpointer", "latest_step", "restore_checkpoint", "save_checkpoint",
    "PreemptionGuard", "StepWatchdog",
    "OptConfig", "apply_updates", "cosine_lr", "init_opt_state",
    "TrainConfig", "loss_fn", "make_train_step",
]
