"""Optimizers (pure-pytree, no external deps): AdamW and Adafactor.

AdamW keeps fp32 first/second moments (ZeRO-1: the launcher shards them over
the data axis).  Adafactor factors the second moment into row/col statistics
— the default for the 340B-class archs where full AdamW state doesn't fit.
Both support global-norm clipping and a linear-warmup cosine schedule.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "cosine_lr", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def cosine_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def _factored_dims(shape):
    """Adafactor factors the two largest trailing dims of >=2D params."""
    if len(shape) < 2:
        return None
    return (len(shape) - 2, len(shape) - 1)


def init_opt_state(params, cfg: OptConfig):
    if cfg.kind == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }
    if cfg.kind == "adafactor":
        def vrow(p):
            d = _factored_dims(p.shape)
            if d is None:
                return jnp.zeros(p.shape, jnp.float32)
            s = list(p.shape)
            s.pop(d[1])
            return jnp.zeros(tuple(s), jnp.float32)

        def vcol(p):
            d = _factored_dims(p.shape)
            if d is None:
                return jnp.zeros((1,), jnp.float32)
            s = list(p.shape)
            s.pop(d[0])
            return jnp.zeros(tuple(s), jnp.float32)

        return {
            "step": jnp.zeros((), jnp.int32),
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
        }
    raise ValueError(cfg.kind)


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm else 1.0

    if cfg.kind == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
        new_state = {"step": step, "m": new_m, "v": new_v}
    else:  # adafactor
        decay = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32) * scale
            d = _factored_dims(p.shape)
            if d is None:
                vr_n = decay * vr + (1 - decay) * g * g
                u = g / (jnp.sqrt(vr_n) + cfg.eps)
                vc_n = vc
            else:
                r, c = d
                vr_n = decay * vr + (1 - decay) * (g * g).mean(axis=c)
                vc_n = decay * vc + (1 - decay) * (g * g).mean(axis=r)
                rfac = vr_n / jnp.maximum(vr_n.mean(axis=-1, keepdims=True), 1e-30)
                vhat = jnp.expand_dims(rfac, c) * jnp.expand_dims(vc_n, r)
                u = g / (jnp.sqrt(vhat) + cfg.eps)
            # update clipping (Adafactor d=1.0)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr_n, vc_n

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_vr = jax.tree.leaves(state["vr"])
        flat_vc = jax.tree.leaves(state["vc"])
        outs = [upd(p, g, vr, vc) for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_state = {
            "step": step,
            "vr": jax.tree.unflatten(tdef, [o[1] for o in outs]),
            "vc": jax.tree.unflatten(tdef, [o[2] for o in outs]),
        }
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, new_state, metrics
