"""Sharded, fault-tolerant checkpointing (msgpack + zstd, no orbax).

Layout:  <dir>/step_<N>/
           manifest.json     — leaf paths, shapes, dtypes, content hashes
           shard_<host>.msgpack — this host's leaf bytes (per-leaf
                               compressed; codec recorded in the manifest)

Guarantees:
  * atomic commit: written to ``step_<N>.tmp`` then renamed;
  * integrity: per-leaf blake2 hashes verified on restore;
  * elasticity: arrays are saved unsharded-logical (host gathers its
    addressable shards); restore re-device_puts under whatever sharding the
    new mesh prescribes, so the device count may change between runs;
  * retention: ``keep`` newest checkpoints survive garbage collection;
  * async: ``save(..., blocking=False)`` hands off to a writer thread.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Optional

import zlib

import msgpack
import numpy as np

try:  # optional: falls back to stdlib zlib when zstandard is not installed
    import zstandard
except ImportError:
    zstandard = None

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "Checkpointer"]


def _make_compressor():
    """(codec_name, compress_fn) — zstd when available, else stdlib zlib."""
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3)
        return "zstd", comp.compress
    return "zlib", lambda raw: zlib.compress(raw, 6)


def _make_decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise ImportError(
                "checkpoint was written with zstd compression but the "
                "'zstandard' package is not installed; pip install zstandard")
        return zstandard.ZstdDecompressor().decompress
    if codec == "zlib":
        return zlib.decompress
    raise IOError(f"unknown checkpoint compression codec {codec!r}")


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory, step: int, tree, *, host_id: int = 0, keep: int = 3,
                    blocking: bool = True) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        tmp = directory / f"step_{step}.tmp"
        final = directory / f"step_{step}"
        tmp.mkdir(parents=True, exist_ok=True)
        codec, compress = _make_compressor()
        manifest = {"step": step, "codec": codec, "leaves": {}}
        payload = {}
        for key, arr in arrays.items():
            raw = arr.tobytes()
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "hash": hashlib.blake2b(raw, digest_size=16).hexdigest(),
            }
            payload[key] = compress(raw)
        # codec-neutral name; the codec lives in the manifest
        with open(tmp / f"shard_{host_id}.msgpack", "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _gc(directory, keep)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    return directory / f"step_{step}"


def _gc(directory: Path, keep: int):
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in directory.glob("step_*")
        if p.is_dir() and not p.name.endswith(".tmp")
    )
    for _s, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if p.is_dir() and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory, step: int, like, *, host_id: int = 0,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic re-sharding onto the current mesh."""
    path = Path(directory) / f"step_{step}"
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    shard = path / f"shard_{host_id}.msgpack"
    if not shard.exists():  # pre-codec checkpoints used a .zst suffix
        shard = path / f"shard_{host_id}.msgpack.zst"
    with open(shard, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    decompress = _make_decompressor(manifest.get("codec", "zstd"))

    flat_like, treedef = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, spec in manifest["leaves"].items():
        if key not in flat_like:
            continue
        raw = decompress(payload[key])
        if hashlib.blake2b(raw, digest_size=16).hexdigest() != spec["hash"]:
            raise IOError(f"checkpoint corruption at leaf {key}")
        arr = np.frombuffer(raw, dtype=spec["dtype"]).reshape(spec["shape"]).copy()
        if key in flat_sh and flat_sh[key] is not None:
            arr = jax.device_put(arr, flat_sh[key])
        out[key] = arr
    missing = set(flat_like) - set(out)
    if missing:
        raise IOError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    leaves = [out[k] for k in flat_like]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """save-every-N helper with preemption flush (see train/fault.py)."""

    def __init__(self, directory, every: int = 100, keep: int = 3, host_id: int = 0):
        self.directory = Path(directory)
        self.every = every
        self.keep = keep
        self.host_id = host_id

    def maybe_save(self, step: int, tree, force: bool = False, blocking: bool = True):
        if force or (self.every and step % self.every == 0 and step > 0):
            return save_checkpoint(self.directory, step, tree, host_id=self.host_id,
                                   keep=self.keep, blocking=blocking)
        return None

    def resume(self, like, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, 0
        tree = restore_checkpoint(self.directory, step, like,
                                  host_id=self.host_id, shardings=shardings)
        return tree, step
