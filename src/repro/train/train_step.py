"""Training step factory: next-token loss, grad accumulation, remat, and
optional int8-compressed gradient reduction.

The step is a pure function jitted with explicit in/out shardings by the
launcher; data parallelism's gradient all-reduce is inserted by GSPMD from
the batch sharding.  With ``compress_grads`` the reduction is made explicit
(shard_map over the data axis) and quantised to int8 with a per-tensor
scale before crossing the wire — see distributed/collectives.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.model import forward_train
from .optimizer import OptConfig, apply_updates

__all__ = ["TrainConfig", "loss_fn", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    aux_loss_coef: float = 0.01
    z_loss_coef: float = 1e-4
    compress_grads: bool = False
    ce_chunk: int = 512          # sequence chunk for the CE scan


def _chunked_ce(x, head, labels, chunk: int):
    """Cross-entropy without materialising (B,S,V): scan over S-chunks.

    x (B,S,d), head (d,V), labels (B,S) -> (nll_mean, z_mean).
    """
    from ..distributed.sharding import logical_constraint as lc

    B, S, d = x.shape
    chunk = min(chunk, S)
    nc = S // chunk
    rem = S - nc * chunk

    def chunk_loss(xc, lb):
        logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        logits = lc(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return (lse - gold).sum(), (lse**2).sum()

    if nc > 0:
        xm = x[:, : nc * chunk].reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
        lm = labels[:, : nc * chunk].reshape(B, nc, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            nll, z = chunk_loss(*inp)
            return (carry[0] + nll, carry[1] + z), None

        (nll, z), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xm, lm))
    else:
        nll = z = jnp.zeros(())
    if rem:
        n2, z2 = chunk_loss(x[:, nc * chunk :], labels[:, nc * chunk :])
        nll, z = nll + n2, z + z2
    n = B * S
    return nll / n, z / n


def loss_fn(params, batch, cfg, tcfg: TrainConfig):
    """Causal LM loss with MoE aux and z-loss (stability, Megatron-style)."""
    from ..models.model import lm_head_of

    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    x, aux = forward_train(params, batch["tokens"], cfg, extras or None,
                           return_hidden=True)
    nll, z = _chunked_ce(x, lm_head_of(params, cfg), batch["labels"], tcfg.ce_chunk)
    loss = nll + tcfg.aux_loss_coef * aux + tcfg.z_loss_coef * z
    return loss, {"nll": nll, "aux": aux, "z": z}


def make_train_step(cfg, opt_cfg: OptConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, cfg, tcfg)
            return g, loss, m

        # gradient accumulation over leading microbatch splits
        def split(x):
            B = x.shape[0]
            mb = tcfg.microbatches
            return x.reshape(mb, B // mb, *x.shape[1:])

        mbatch = jax.tree.map(split, batch)

        def body(carry, mb):
            g_acc, l_acc = carry
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, cfg, tcfg)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), m

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss_sum), ms = jax.lax.scan(body, (g0, jnp.zeros(())), mbatch)
        inv = 1.0 / tcfg.microbatches
        g = jax.tree.map(lambda x: x * inv, g)
        m = jax.tree.map(lambda x: x.mean(), ms)
        return g, loss_sum * inv, m

    def step(params, opt_state, batch):
        grads, loss, m = grads_of(params, batch)
        if tcfg.compress_grads:
            from ..distributed.collectives import fake_quantize_grads

            grads = fake_quantize_grads(grads)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **m, **om}
        return params, opt_state, metrics

    return step
