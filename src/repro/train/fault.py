"""Fault tolerance: preemption handling, straggler mitigation knobs, and
elastic restart.

* ``PreemptionGuard`` — installs SIGTERM/SIGINT handlers; the training loop
  polls ``should_stop`` and flushes a checkpoint before exiting (TPU
  preemption notice pattern).
* ``elastic_restore`` — resume from the newest valid checkpoint onto a mesh
  of a *different* size: checkpoints store logical arrays, so restore is a
  device_put under the new shardings (see checkpoint.py).
* Straggler mitigation lives in the data pipeline (deterministic skip-ahead,
  no cross-host barrier on the input queue) and in the launcher's
  ``--watchdog`` (re-exec on a hung step; wall-clock budget per step).
"""
from __future__ import annotations

import signal
import threading

__all__ = ["PreemptionGuard", "StepWatchdog"]


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread
        return self

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


class StepWatchdog:
    """Detects hung/straggling steps: if a step exceeds ``budget_s`` the
    ``on_timeout`` callback fires (checkpoint + abort, or re-dispatch)."""

    def __init__(self, budget_s: float, on_timeout=None):
        self.budget_s = budget_s
        self.on_timeout = on_timeout
        self._timer = None
        self.timed_out = False

    def _fire(self):
        self.timed_out = True
        if self.on_timeout:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.budget_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer:
            self._timer.cancel()
        return False
