"""Pallas TPU kernel for the GLU submatrix (subcolumn) update.

This is the paper's central compute: for every update triple in a level,
``A(i,k) -= A(i,j) * A(j,k)``.  On the GPU this is an atomic MAC scatter; on
TPU we make it collision-free by segmenting updates per *destination column*
(the plan already stores them destination-major) and accumulating inside
VMEM with a one-hot matmul — the MXU performs the scatter-add.

Geometry (chosen at plan time per level — the TPU analogue of the paper's
three adaptive modes):
  D  — destination columns processed by the grid's first axis
  R  — padded updates per destination column (multiple of RC=256)
  C  — padded destination column length, split into CB=512 blocks
Type B levels compile with large D / small R,C; type C levels with small D /
large R,C (panel).  Type A levels bypass this kernel entirely (flat XLA
scatter-add is optimal there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segmented_accumulate", "RC", "CB"]

RC = 256   # contribution chunk (MXU contraction dim)
CB = 512   # destination column block (MXU output dim)


def _kernel(cv_ref, cb_ref, dl_ref, out_ref, *, R: int, cb_size: int):
    """One (destination column, column block) cell."""
    blk = pl.program_id(1)
    base = blk * cb_size
    dtype = cv_ref.dtype
    acc = jnp.zeros((1, cb_size), dtype=dtype)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (RC, cb_size), 1) + base
    for rc in range(R // RC):
        dl = dl_ref[0, rc * RC : (rc + 1) * RC]            # (RC,) int32
        onehot = (dl[:, None] == col_ids).astype(dtype)     # (RC, CB)
        contrib = cb_ref[0, rc * RC : (rc + 1) * RC][None, :]
        acc = acc + jnp.dot(contrib, onehot, preferred_element_type=dtype)
    out_ref[...] = cv_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def segmented_accumulate(col_vals, contribs, didx_local, *, interpret: bool = True):
    """col_vals (D,C) += scatter(contribs (D,R) at didx_local (D,R)).

    Padding: contribs 0-padded; didx_local padded with >= C (never matches).
    C must be a multiple of CB or < CB (then one block); R a multiple of RC.
    """
    D, C = col_vals.shape
    _, R = contribs.shape
    cb_size = min(C, CB)
    assert C % cb_size == 0 and R % RC == 0, (C, R)
    n_cb = C // cb_size
    kernel = functools.partial(_kernel, R=R, cb_size=cb_size)
    return pl.pallas_call(
        kernel,
        grid=(D, n_cb),
        in_specs=[
            pl.BlockSpec((1, cb_size), lambda d, b: (d, b)),
            pl.BlockSpec((1, R), lambda d, b: (d, 0)),
            pl.BlockSpec((1, R), lambda d, b: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, cb_size), lambda d, b: (d, b)),
        out_shape=jax.ShapeDtypeStruct((D, C), col_vals.dtype),
        interpret=interpret,
    )(col_vals, contribs, didx_local)
