"""Jit'd wrappers around the Pallas kernels.

``level_update`` consumes the host-precomputed (D, R, C) segmented layout
(built once per plan in ``JaxFactorizer``): normalisation happens as a flat
XLA op (cheap), contributions are gathered on the (D, R) grid, the Pallas
kernel performs the per-destination-column accumulation, and the updated
segments scatter back (segments are disjoint, so the scatter is race-free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..sparse.layout import pabs, pdiv, pmul
from .dense_lu import dense_lu
from .level_update import segmented_accumulate

__all__ = [
    "level_update",
    "level_update_body",
    "level_update_batched",
    "level_update_batched_body",
    "level_update_planar",
    "level_update_planar_body",
    "level_update_planar_batched",
    "level_update_planar_batched_body",
    "dense_lu",
    "spmv",
    "perturb_diags",
    "perturb_diags_batched",
    "perturb_diags_planar",
    "perturb_diags_planar_batched",
    "factor_stats",
    "factor_stats_batched",
    "factor_stats_planar",
    "factor_stats_planar_batched",
    "masked_correction",
]


# The ``*_body`` functions are the un-jitted step implementations: the
# whole-schedule executors (core/factorize.py) inline them inside ONE fused
# jitted program, while the jitted module-level wrappers below remain the
# per-group dispatch path (and keep their donation semantics).

def level_update_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """One GLU level via the segmented Pallas kernel.

    vals:          (nnz,) filled value array
    norm_idx/diag: (Pn,)  flat normalisation indices (padded with nnz)
    lidx2d/uidx2d: (D,R)  value indices of each update's L and U operand
    didx_local:    (D,R)  position of each update inside its destination
                          column segment (padded with >= C)
    col_positions: (D,C)  flat value indices of the destination segments
                          (padded with nnz)
    """
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(lv / dv, mode="drop")

    l = vals.at[lidx2d].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx2d].get(mode="fill", fill_value=0.0)
    contribs = -(l * u)
    col_vals = vals.at[col_positions].get(mode="fill", fill_value=0.0)
    out = segmented_accumulate(col_vals, contribs, didx_local, interpret=interpret)
    return vals.at[col_positions].set(out, mode="drop")


level_update = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_body)


def level_update_batched_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """One GLU level for a whole batch of matrices sharing the plan.

    ``vals`` is (B, nnz); the layout arrays are the same as
    :func:`level_update` and shared across the batch.  The batch axis folds
    into the kernel's destination-column grid axis — contributions become
    (B*D, R) and segments (B*D, C) — so the whole batch is ONE kernel
    launch with grid (B*D, C//CB), not B launches.
    """
    B = vals.shape[0]
    D, R = lidx2d.shape
    C = col_positions.shape[1]
    lv = vals.at[:, norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[:, norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[:, norm_idx].set(lv / dv, mode="drop")

    l = vals.at[:, lidx2d].get(mode="fill", fill_value=0.0)       # (B, D, R)
    u = vals.at[:, uidx2d].get(mode="fill", fill_value=0.0)
    contribs = (-(l * u)).reshape(B * D, R)
    col_vals = vals.at[:, col_positions].get(mode="fill", fill_value=0.0)
    dl = jnp.broadcast_to(didx_local, (B, D, R)).reshape(B * D, R)
    out = segmented_accumulate(col_vals.reshape(B * D, C), contribs, dl,
                               interpret=interpret)
    return vals.at[:, col_positions].set(out.reshape(B, D, C), mode="drop")


level_update_batched = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_batched_body)


# -- planar complex twins ----------------------------------------------------
#
# ``vals`` carries split re/im planes in a trailing axis: (nnz, 2) single,
# (B, nnz, 2) batched.  Row gathers make the index machinery identical to
# the native path; the only new move is folding the PLANE axis into the
# Pallas kernel's destination-column grid axis — exactly like the batch
# fold above — so the dtype-generic real ``segmented_accumulate`` kernel
# runs complex levels unchanged: contributions become (2*D, R) [(B*2*D, R)
# batched] and segments (2*D, C).  Real and imaginary accumulations are
# independent (the complex cross terms live in ``pmul``, applied BEFORE the
# scatter), so per-plane segmented accumulation is exact.

def level_update_planar_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """Planar twin of :func:`level_update_body`: ``vals`` is (nnz, 2)."""
    D, R = lidx2d.shape
    C = col_positions.shape[1]
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(pdiv(lv, dv), mode="drop")

    l = vals.at[lidx2d].get(mode="fill", fill_value=0.0)      # (D, R, 2)
    u = vals.at[uidx2d].get(mode="fill", fill_value=0.0)
    contribs = jnp.moveaxis(-pmul(l, u), -1, 0).reshape(2 * D, R)
    col_vals = vals.at[col_positions].get(mode="fill", fill_value=0.0)
    cv = jnp.moveaxis(col_vals, -1, 0).reshape(2 * D, C)
    dl = jnp.broadcast_to(didx_local, (2, D, R)).reshape(2 * D, R)
    out = segmented_accumulate(cv, contribs, dl, interpret=interpret)
    out = jnp.moveaxis(out.reshape(2, D, C), 0, -1)           # (D, C, 2)
    return vals.at[col_positions].set(out, mode="drop")


level_update_planar = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_planar_body)


def level_update_planar_batched_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """Planar batched twin: ``vals`` is (B, nnz, 2); batch AND plane axes
    fold into the kernel grid — ONE launch with grid (B*2*D, C//CB)."""
    B = vals.shape[0]
    D, R = lidx2d.shape
    C = col_positions.shape[1]
    lv = vals.at[:, norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[:, norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[:, norm_idx].set(pdiv(lv, dv), mode="drop")

    l = vals.at[:, lidx2d].get(mode="fill", fill_value=0.0)   # (B, D, R, 2)
    u = vals.at[:, uidx2d].get(mode="fill", fill_value=0.0)
    contribs = jnp.moveaxis(-pmul(l, u), -1, 1).reshape(B * 2 * D, R)
    col_vals = vals.at[:, col_positions].get(mode="fill", fill_value=0.0)
    cv = jnp.moveaxis(col_vals, -1, 1).reshape(B * 2 * D, C)
    dl = jnp.broadcast_to(didx_local, (B, 2, D, R)).reshape(B * 2 * D, R)
    out = segmented_accumulate(cv, contribs, dl, interpret=interpret)
    out = jnp.moveaxis(out.reshape(B, 2, D, C), 1, -1)        # (B, D, C, 2)
    return vals.at[:, col_positions].set(out, mode="drop")


level_update_planar_batched = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_planar_batched_body)


@functools.partial(jax.jit, static_argnames=("n_rows",))
def spmv(row_ids, colidx, a_vals, x, *, n_rows: int):
    """CSR-ish SpMV: y[row_ids] += a_vals * x[colidx] (segment-sum form)."""
    prods = a_vals * x[colidx]
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows)


# --------------------------------------------------------------------------
# Numerical-robustness primitives (diagnostics + static pivoting)
# --------------------------------------------------------------------------

def _perturb_diags_body(vals, diag_idx, tau):
    """Static pivot perturbation (SuperLU_DIST-style): any diagonal with
    ``|d| < tau`` is replaced by ``tau * d/|d|`` — magnitude tau, phase
    preserved (for real values that is ``sign(d) * tau``; exact zeros bump
    to ``+tau``) — instead of poisoning the factors with inf/NaN.
    ``diag_idx`` is padded with ``nnz`` (one past the value array); padded
    slots are masked out explicitly so they contribute neither bumps nor
    counts whatever tau is."""
    valid = diag_idx < vals.shape[-1]
    d = vals.at[diag_idx].get(mode="fill", fill_value=1.0)
    mag = jnp.abs(d)
    tiny = (mag < tau) & valid
    phase = jnp.where(mag > 0, d / jnp.where(mag > 0, mag, 1.0), 1.0)
    bumped = jnp.where(tiny, (phase * tau).astype(vals.dtype), d)
    vals = vals.at[diag_idx].set(bumped, mode="drop")
    return vals, jnp.sum(tiny, dtype=jnp.int32)


perturb_diags = functools.partial(jax.jit, donate_argnums=(0,))(
    _perturb_diags_body)
perturb_diags_batched = functools.partial(jax.jit, donate_argnums=(0,))(
    jax.vmap(_perturb_diags_body, in_axes=(0, None, 0)))


def _perturb_diags_planar_body(vals, diag_idx, tau):
    """Planar twin of :func:`_perturb_diags_body`: ``vals`` is (nnz, 2),
    ``tau`` a REAL threshold.  Same bump rule on planes — magnitude via
    hypot, phase per plane (re/|d|, im/|d|; exact zeros bump to (+tau, 0)),
    so a planar factorization perturbs exactly where the native one does."""
    valid = diag_idx < vals.shape[-2]
    d = vals.at[diag_idx].get(mode="fill", fill_value=1.0)     # (P, 2)
    dr, di = d[..., 0], d[..., 1]
    mag = jnp.hypot(dr, di)
    tiny = (mag < tau) & valid
    safe = jnp.where(mag > 0, mag, 1.0)
    phr = jnp.where(mag > 0, dr / safe, 1.0)
    phi = jnp.where(mag > 0, di / safe, 0.0)
    bumped = jnp.stack([phr * tau, phi * tau], axis=-1).astype(vals.dtype)
    out = jnp.where(tiny[..., None], bumped, d)
    vals = vals.at[diag_idx].set(out, mode="drop")
    return vals, jnp.sum(tiny, dtype=jnp.int32)


perturb_diags_planar = functools.partial(jax.jit, donate_argnums=(0,))(
    _perturb_diags_planar_body)
perturb_diags_planar_batched = functools.partial(jax.jit, donate_argnums=(0,))(
    jax.vmap(_perturb_diags_planar_body, in_axes=(0, None, 0)))


def _factor_stats_body(vals, diag_idx, a_max):
    """One fused reduction pass over the factored values: element pivot
    growth ``max|LU| / max|A|`` and the smallest post-factorization
    diagonal magnitude (the two no-pivot health numbers)."""
    d = jnp.abs(vals[diag_idx])
    growth = jnp.max(jnp.abs(vals)) / jnp.maximum(a_max, jnp.finfo(vals.dtype).tiny)
    return growth, jnp.min(d)


factor_stats = jax.jit(_factor_stats_body)
factor_stats_batched = jax.jit(jax.vmap(_factor_stats_body,
                                        in_axes=(0, None, 0)))


def _factor_stats_planar_body(vals, diag_idx, a_max):
    """Planar twin of :func:`_factor_stats_body`: ``vals`` is (nnz, 2)."""
    mag = pabs(vals)
    d = mag[diag_idx]
    growth = jnp.max(mag) / jnp.maximum(a_max, jnp.finfo(mag.dtype).tiny)
    return growth, jnp.min(d)


factor_stats_planar = jax.jit(_factor_stats_planar_body)
factor_stats_planar_batched = jax.jit(jax.vmap(_factor_stats_planar_body,
                                               in_axes=(0, None, 0)))


@functools.partial(jax.jit, donate_argnums=(0,))
def masked_correction(x, d, berr, tol):
    """``x + d`` where the solve is still above tolerance, ``x`` unchanged
    where it has converged — the device-side convergence mask that lets
    iterative refinement issue several sweeps without a host sync per
    sweep.  ``berr`` is a scalar (single solve) or a (B,)/(K,) vector
    (batched / many-rhs), broadcast across the trailing axes of ``x``."""
    mask = berr > tol
    mask = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    return x + jnp.where(mask, d, jnp.zeros((), dtype=x.dtype))
