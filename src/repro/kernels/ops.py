"""Jit'd wrappers around the Pallas kernels.

``level_update`` consumes the host-precomputed (D, R, C) segmented layout
(built once per plan in ``JaxFactorizer``): normalisation happens as a flat
XLA op (cheap), contributions are gathered on the (D, R) grid, the Pallas
kernel performs the per-destination-column accumulation, and the updated
segments scatter back (segments are disjoint, so the scatter is race-free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dense_lu import dense_lu
from .level_update import segmented_accumulate

__all__ = [
    "level_update",
    "level_update_body",
    "level_update_batched",
    "level_update_batched_body",
    "dense_lu",
    "spmv",
    "perturb_diags",
    "perturb_diags_batched",
    "factor_stats",
    "factor_stats_batched",
    "masked_correction",
]


# The ``*_body`` functions are the un-jitted step implementations: the
# whole-schedule executors (core/factorize.py) inline them inside ONE fused
# jitted program, while the jitted module-level wrappers below remain the
# per-group dispatch path (and keep their donation semantics).

def level_update_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """One GLU level via the segmented Pallas kernel.

    vals:          (nnz,) filled value array
    norm_idx/diag: (Pn,)  flat normalisation indices (padded with nnz)
    lidx2d/uidx2d: (D,R)  value indices of each update's L and U operand
    didx_local:    (D,R)  position of each update inside its destination
                          column segment (padded with >= C)
    col_positions: (D,C)  flat value indices of the destination segments
                          (padded with nnz)
    """
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(lv / dv, mode="drop")

    l = vals.at[lidx2d].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx2d].get(mode="fill", fill_value=0.0)
    contribs = -(l * u)
    col_vals = vals.at[col_positions].get(mode="fill", fill_value=0.0)
    out = segmented_accumulate(col_vals, contribs, didx_local, interpret=interpret)
    return vals.at[col_positions].set(out, mode="drop")


level_update = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_body)


def level_update_batched_body(
    vals,
    norm_idx,
    norm_diag,
    lidx2d,
    uidx2d,
    didx_local,
    col_positions,
    *,
    interpret: bool = True,
):
    """One GLU level for a whole batch of matrices sharing the plan.

    ``vals`` is (B, nnz); the layout arrays are the same as
    :func:`level_update` and shared across the batch.  The batch axis folds
    into the kernel's destination-column grid axis — contributions become
    (B*D, R) and segments (B*D, C) — so the whole batch is ONE kernel
    launch with grid (B*D, C//CB), not B launches.
    """
    B = vals.shape[0]
    D, R = lidx2d.shape
    C = col_positions.shape[1]
    lv = vals.at[:, norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[:, norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[:, norm_idx].set(lv / dv, mode="drop")

    l = vals.at[:, lidx2d].get(mode="fill", fill_value=0.0)       # (B, D, R)
    u = vals.at[:, uidx2d].get(mode="fill", fill_value=0.0)
    contribs = (-(l * u)).reshape(B * D, R)
    col_vals = vals.at[:, col_positions].get(mode="fill", fill_value=0.0)
    dl = jnp.broadcast_to(didx_local, (B, D, R)).reshape(B * D, R)
    out = segmented_accumulate(col_vals.reshape(B * D, C), contribs, dl,
                               interpret=interpret)
    return vals.at[:, col_positions].set(out.reshape(B, D, C), mode="drop")


level_update_batched = functools.partial(
    jax.jit, static_argnames=("interpret",), donate_argnums=(0,))(
    level_update_batched_body)


@functools.partial(jax.jit, static_argnames=("n_rows",))
def spmv(row_ids, colidx, a_vals, x, *, n_rows: int):
    """CSR-ish SpMV: y[row_ids] += a_vals * x[colidx] (segment-sum form)."""
    prods = a_vals * x[colidx]
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows)


# --------------------------------------------------------------------------
# Numerical-robustness primitives (diagnostics + static pivoting)
# --------------------------------------------------------------------------

def _perturb_diags_body(vals, diag_idx, tau):
    """Static pivot perturbation (SuperLU_DIST-style): any diagonal with
    ``|d| < tau`` is replaced by ``tau * d/|d|`` — magnitude tau, phase
    preserved (for real values that is ``sign(d) * tau``; exact zeros bump
    to ``+tau``) — instead of poisoning the factors with inf/NaN.
    ``diag_idx`` is padded with ``nnz`` (one past the value array); padded
    slots are masked out explicitly so they contribute neither bumps nor
    counts whatever tau is."""
    valid = diag_idx < vals.shape[-1]
    d = vals.at[diag_idx].get(mode="fill", fill_value=1.0)
    mag = jnp.abs(d)
    tiny = (mag < tau) & valid
    phase = jnp.where(mag > 0, d / jnp.where(mag > 0, mag, 1.0), 1.0)
    bumped = jnp.where(tiny, (phase * tau).astype(vals.dtype), d)
    vals = vals.at[diag_idx].set(bumped, mode="drop")
    return vals, jnp.sum(tiny, dtype=jnp.int32)


perturb_diags = functools.partial(jax.jit, donate_argnums=(0,))(
    _perturb_diags_body)
perturb_diags_batched = functools.partial(jax.jit, donate_argnums=(0,))(
    jax.vmap(_perturb_diags_body, in_axes=(0, None, 0)))


def _factor_stats_body(vals, diag_idx, a_max):
    """One fused reduction pass over the factored values: element pivot
    growth ``max|LU| / max|A|`` and the smallest post-factorization
    diagonal magnitude (the two no-pivot health numbers)."""
    d = jnp.abs(vals[diag_idx])
    growth = jnp.max(jnp.abs(vals)) / jnp.maximum(a_max, jnp.finfo(vals.dtype).tiny)
    return growth, jnp.min(d)


factor_stats = jax.jit(_factor_stats_body)
factor_stats_batched = jax.jit(jax.vmap(_factor_stats_body,
                                        in_axes=(0, None, 0)))


@functools.partial(jax.jit, donate_argnums=(0,))
def masked_correction(x, d, berr, tol):
    """``x + d`` where the solve is still above tolerance, ``x`` unchanged
    where it has converged — the device-side convergence mask that lets
    iterative refinement issue several sweeps without a host sync per
    sweep.  ``berr`` is a scalar (single solve) or a (B,)/(K,) vector
    (batched / many-rhs), broadcast across the trailing axes of ``x``."""
    mask = berr > tol
    mask = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    return x + jnp.where(mask, d, jnp.zeros((), dtype=x.dtype))
