"""Pallas TPU kernel: blocked unpivoted dense LU for the trailing submatrix.

Beyond-paper optimization (switch-to-dense): near the end of factorization
the trailing submatrix of circuit matrices becomes dense-ish (the paper's
type C levels).  Instead of long chains of tiny sparse levels, we gather the
trailing block into a dense tile and finish with a blocked right-looking LU
whose rank-B updates run on the MXU.

Layout: in-place LU, L strictly below the diagonal (unit diagonal implied),
U on/above.  No pivoting — the GLU flow guarantees numerically safe pivots
via MC64 + diagonal dominance, same assumption as the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_lu", "dense_lu_planar", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = 128


def _panel_factor(m, k0, B, N):
    """Factor the B-wide panel [k0:, k0:k0+B] in place (unblocked, vectorised
    over rows)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)

    def col_step(jj, m):
        j = k0 + jj
        piv = m[j, j]
        col = m[:, j][:, None]                       # (N,1)
        lcol = jnp.where(rows > j, col / piv, col)
        m = jax.lax.dynamic_update_slice(m, lcol, (0, j))
        # rank-1 update restricted to the remaining panel columns
        row = m[j, :][None, :]                       # (1,N)
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)
        row_m = jnp.where((cols > j) & (cols < k0 + B), row, 0.0)
        l_m = jnp.where(rows > j, lcol, 0.0)
        return m - l_m @ row_m

    return jax.lax.fori_loop(0, B, col_step, m)


def _trsm_rows(m, k0, B, N):
    """Rows k0:k0+B of the trailing columns: U12 = L11^{-1} A12 (unit lower).

    Forward substitution down the B rows of the diagonal block.
    """
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)

    def row_step(ii, m):
        i = k0 + ii
        # row_i -= sum_{t<i, t>=k0} L(i,t) * row_t   (already-final rows)
        acc = jnp.zeros((1, N), m.dtype)

        def inner(tt, acc):
            t = k0 + tt
            lit = m[i, t]
            return acc + lit * jnp.where(cols >= k0 + B, m[t, :][None, :], 0.0)

        acc = jax.lax.fori_loop(0, ii, inner, acc)
        new_row = m[i, :][None, :] - acc
        new_row = jnp.where(cols >= k0 + B, new_row, m[i, :][None, :])
        return jax.lax.dynamic_update_slice(m, new_row, (i, 0))

    return jax.lax.fori_loop(0, B, row_step, m)


def _lu_kernel(a_ref, out_ref, *, N: int, B: int):
    m = a_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)
    nblk = N // B
    for kb in range(nblk):
        k0 = kb * B
        m = _panel_factor(m, k0, B, N)
        if kb < nblk - 1:
            m = _trsm_rows(m, k0, B, N)
            # trailing update A22 -= L21 @ U12 on the MXU
            lmask = (rows >= k0 + B) & (cols >= k0) & (cols < k0 + B)
            umask = (rows >= k0) & (rows < k0 + B) & (cols >= k0 + B)
            L21 = jnp.where(lmask, m, 0.0)
            U12 = jnp.where(umask, m, 0.0)
            m = m - jnp.dot(L21, U12, preferred_element_type=m.dtype)
    out_ref[...] = m


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dense_lu(a, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """In-place-layout unpivoted LU of a dense (N, N) tile."""
    N = a.shape[0]
    B = min(block, N)
    assert N % B == 0, (N, B)
    kernel = functools.partial(_lu_kernel, N=N, B=B)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((N, N), a.dtype),
        interpret=interpret,
    )(a)


# --------------------------------------------------------------------------
# Planar complex twin: the SAME blocked algorithm on split re/im planes.
# The kernel sees only real operands — complex multiply is 4 real matmuls +
# sign on the MXU, the pivot reciprocal is conj(p) / (re^2 + im^2) — which
# is what lets complex dense tails stay on the Pallas path (TPU kernels take
# no complex operands).
# --------------------------------------------------------------------------

def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _panel_factor_planar(mr, mi, k0, B, N):
    """Planar twin of :func:`_panel_factor` on (N, N) re/im planes."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)

    def col_step(jj, m):
        mr, mi = m
        j = k0 + jj
        pr, pi = mr[j, j], mi[j, j]
        inv = 1.0 / (pr * pr + pi * pi)
        cr = mr[:, j][:, None]
        ci = mi[:, j][:, None]
        qr = (cr * pr + ci * pi) * inv
        qi = (ci * pr - cr * pi) * inv
        lr = jnp.where(rows > j, qr, cr)
        li = jnp.where(rows > j, qi, ci)
        mr = jax.lax.dynamic_update_slice(mr, lr, (0, j))
        mi = jax.lax.dynamic_update_slice(mi, li, (0, j))
        # rank-1 update restricted to the remaining panel columns
        row_mask = (cols > j) & (cols < k0 + B)
        rr = jnp.where(row_mask, mr[j, :][None, :], 0.0)
        ri = jnp.where(row_mask, mi[j, :][None, :], 0.0)
        lmr = jnp.where(rows > j, lr, 0.0)
        lmi = jnp.where(rows > j, li, 0.0)
        ur, ui = _cmul(lmr, lmi, rr, ri)
        return mr - ur, mi - ui

    return jax.lax.fori_loop(0, B, col_step, (mr, mi))


def _trsm_rows_planar(mr, mi, k0, B, N):
    """Planar twin of :func:`_trsm_rows`."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)

    def row_step(ii, m):
        mr, mi = m
        i = k0 + ii
        accr = jnp.zeros((1, N), mr.dtype)
        acci = jnp.zeros((1, N), mi.dtype)

        def inner(tt, acc):
            accr, acci = acc
            t = k0 + tt
            lr, li = mr[i, t], mi[i, t]
            tr = jnp.where(cols >= k0 + B, mr[t, :][None, :], 0.0)
            ti = jnp.where(cols >= k0 + B, mi[t, :][None, :], 0.0)
            return accr + (lr * tr - li * ti), acci + (lr * ti + li * tr)

        accr, acci = jax.lax.fori_loop(0, ii, inner, (accr, acci))
        nr = mr[i, :][None, :] - accr
        ni = mi[i, :][None, :] - acci
        nr = jnp.where(cols >= k0 + B, nr, mr[i, :][None, :])
        ni = jnp.where(cols >= k0 + B, ni, mi[i, :][None, :])
        return (jax.lax.dynamic_update_slice(mr, nr, (i, 0)),
                jax.lax.dynamic_update_slice(mi, ni, (i, 0)))

    return jax.lax.fori_loop(0, B, row_step, (mr, mi))


def _lu_kernel_planar(a_ref, out_ref, *, N: int, B: int):
    m = a_ref[...]                               # (2, N, N)
    mr, mi = m[0], m[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (N, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, N), 1)
    nblk = N // B
    for kb in range(nblk):
        k0 = kb * B
        mr, mi = _panel_factor_planar(mr, mi, k0, B, N)
        if kb < nblk - 1:
            mr, mi = _trsm_rows_planar(mr, mi, k0, B, N)
            # trailing update A22 -= L21 @ U12: 4 real matmuls on the MXU
            lmask = (rows >= k0 + B) & (cols >= k0) & (cols < k0 + B)
            umask = (rows >= k0) & (rows < k0 + B) & (cols >= k0 + B)
            L21r = jnp.where(lmask, mr, 0.0)
            L21i = jnp.where(lmask, mi, 0.0)
            U12r = jnp.where(umask, mr, 0.0)
            U12i = jnp.where(umask, mi, 0.0)
            dot = functools.partial(jnp.dot, preferred_element_type=mr.dtype)
            mr = mr - (dot(L21r, U12r) - dot(L21i, U12i))
            mi = mi - (dot(L21r, U12i) + dot(L21i, U12r))
    out_ref[...] = jnp.stack([mr, mi])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dense_lu_planar(a, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Unpivoted LU of a complex (N, N) tile stored as (2, N, N) planes."""
    N = a.shape[-1]
    B = min(block, N)
    assert a.shape == (2, N, N) and N % B == 0, (a.shape, B)
    kernel = functools.partial(_lu_kernel_planar, N=N, B=B)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((2, N, N), a.dtype),
        interpret=interpret,
    )(a)
