# Pallas TPU kernels for the paper's compute hot spots.
from . import ops, ref
from .dense_lu import dense_lu, dense_lu_planar
from .level_update import segmented_accumulate

__all__ = ["ops", "ref", "dense_lu", "dense_lu_planar", "segmented_accumulate"]
