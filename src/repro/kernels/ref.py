"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["level_update_ref", "segmented_accumulate_ref", "dense_lu_ref", "spmv_ref"]


def level_update_ref(vals, norm_idx, norm_diag, lidx, uidx, didx):
    """One GLU level: normalise L parts, then apply all MAC updates.

    Padded index slots hold ``len(vals)`` (drop/fill semantics).
    """
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(lv / dv, mode="drop")
    l = vals.at[lidx].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx].get(mode="fill", fill_value=0.0)
    return vals.at[didx].add(-l * u, mode="drop")


def segmented_accumulate_ref(col_vals, contribs, didx_local):
    """Per-destination-column accumulation (the Pallas kernel's inner op).

    col_vals:   (D, C)  current destination-column segments
    contribs:   (D, R)  update contributions (already -l*u), padded with 0
    didx_local: (D, R)  position of each contribution within its column,
                        padded with C (out of range -> dropped)
    returns     (D, C)  updated segments
    """
    D, C = col_vals.shape

    def per_col(cv, cb, dl):
        return cv.at[dl].add(cb, mode="drop")

    return jax.vmap(per_col)(col_vals, contribs, didx_local)


def dense_lu_ref(a):
    """Unpivoted dense LU, in-place layout (L strictly below diag, unit
    diagonal implied; U on and above). Pure lax.fori_loop reference."""
    n = a.shape[0]

    def step(j, m):
        piv = m[j, j]
        col = m[:, j]
        i = jnp.arange(n)
        lcol = jnp.where(i > j, col / piv, col)
        m = m.at[:, j].set(lcol)
        row = jnp.where(i > j, m[j, :], 0.0)
        lmask = jnp.where(i > j, lcol, 0.0)
        return m - jnp.outer(lmask, row)

    return jax.lax.fori_loop(0, n, step, a)


def spmv_ref(indptr_rows, colidx, vals, x, n_rows):
    """CSR SpMV oracle: y = A @ x via segment-sum."""
    row_id = jnp.searchsorted(indptr_rows, jnp.arange(len(colidx)), side="right") - 1
    prods = vals * x[colidx]
    return jax.ops.segment_sum(prods, row_id, num_segments=n_rows)
