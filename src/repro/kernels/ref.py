"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["level_update_ref", "segmented_accumulate_ref", "dense_lu_ref",
           "dense_lu_planar_ref", "spmv_ref"]


def level_update_ref(vals, norm_idx, norm_diag, lidx, uidx, didx):
    """One GLU level: normalise L parts, then apply all MAC updates.

    Padded index slots hold ``len(vals)`` (drop/fill semantics).
    """
    lv = vals.at[norm_idx].get(mode="fill", fill_value=0.0)
    dv = vals.at[norm_diag].get(mode="fill", fill_value=1.0)
    vals = vals.at[norm_idx].set(lv / dv, mode="drop")
    l = vals.at[lidx].get(mode="fill", fill_value=0.0)
    u = vals.at[uidx].get(mode="fill", fill_value=0.0)
    return vals.at[didx].add(-l * u, mode="drop")


def segmented_accumulate_ref(col_vals, contribs, didx_local):
    """Per-destination-column accumulation (the Pallas kernel's inner op).

    col_vals:   (D, C)  current destination-column segments
    contribs:   (D, R)  update contributions (already -l*u), padded with 0
    didx_local: (D, R)  position of each contribution within its column,
                        padded with C (out of range -> dropped)
    returns     (D, C)  updated segments
    """
    D, C = col_vals.shape

    def per_col(cv, cb, dl):
        return cv.at[dl].add(cb, mode="drop")

    return jax.vmap(per_col)(col_vals, contribs, didx_local)


def dense_lu_ref(a):
    """Unpivoted dense LU, in-place layout (L strictly below diag, unit
    diagonal implied; U on and above). Pure lax.fori_loop reference."""
    n = a.shape[0]

    def step(j, m):
        piv = m[j, j]
        col = m[:, j]
        i = jnp.arange(n)
        lcol = jnp.where(i > j, col / piv, col)
        m = m.at[:, j].set(lcol)
        row = jnp.where(i > j, m[j, :], 0.0)
        lmask = jnp.where(i > j, lcol, 0.0)
        return m - jnp.outer(lmask, row)

    return jax.lax.fori_loop(0, n, step, a)


def dense_lu_planar_ref(a):
    """Planar twin of :func:`dense_lu_ref`: ``a`` is (2, N, N) split re/im
    planes of a complex tile.  Complex multiply = 4 real outer products +
    sign; pivot reciprocal via ``conj(p) / (re^2 + im^2)``."""
    n = a.shape[-1]

    def step(j, m):
        mr, mi = m[0], m[1]
        pr, pi = mr[j, j], mi[j, j]
        inv = 1.0 / (pr * pr + pi * pi)
        cr, ci = mr[:, j], mi[:, j]
        qr = (cr * pr + ci * pi) * inv
        qi = (ci * pr - cr * pi) * inv
        i = jnp.arange(n)
        lr = jnp.where(i > j, qr, cr)
        li = jnp.where(i > j, qi, ci)
        mr = mr.at[:, j].set(lr)
        mi = mi.at[:, j].set(li)
        rr = jnp.where(i > j, mr[j, :], 0.0)
        ri = jnp.where(i > j, mi[j, :], 0.0)
        lmr = jnp.where(i > j, lr, 0.0)
        lmi = jnp.where(i > j, li, 0.0)
        mr = mr - (jnp.outer(lmr, rr) - jnp.outer(lmi, ri))
        mi = mi - (jnp.outer(lmr, ri) + jnp.outer(lmi, rr))
        return jnp.stack([mr, mi])

    return jax.lax.fori_loop(0, n, step, a)


def spmv_ref(indptr_rows, colidx, vals, x, n_rows):
    """CSR SpMV oracle: y = A @ x via segment-sum."""
    row_id = jnp.searchsorted(indptr_rows, jnp.arange(len(colidx)), side="right") - 1
    prods = vals * x[colidx]
    return jax.ops.segment_sum(prods, row_id, num_segments=n_rows)
