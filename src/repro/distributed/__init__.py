from .collectives import (
    compressed_psum,
    dequantize_int8,
    fake_quantize_grads,
    psum_exact,
    quantize_int8,
)
from .scenario import ScenarioSharding, make_scenario_sharding, make_sweep_mesh
from .sharding import (
    DEFAULT_RULES,
    axis_env,
    logical_constraint,
    make_rules,
    sharding_for_spec,
    spec_struct,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "ScenarioSharding",
    "axis_env",
    "compressed_psum",
    "dequantize_int8",
    "fake_quantize_grads",
    "logical_constraint",
    "make_rules",
    "make_scenario_sharding",
    "make_sweep_mesh",
    "psum_exact",
    "quantize_int8",
    "sharding_for_spec",
    "spec_struct",
    "tree_shardings",
]
