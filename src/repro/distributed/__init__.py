from .sharding import (
    DEFAULT_RULES,
    axis_env,
    logical_constraint,
    make_rules,
    sharding_for_spec,
    spec_struct,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "axis_env",
    "logical_constraint",
    "make_rules",
    "sharding_for_spec",
    "spec_struct",
    "tree_shardings",
]
