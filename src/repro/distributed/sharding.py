"""Logical-axis sharding: one rules table maps logical names to mesh axes.

Models annotate activations with ``logical_constraint(x, *names)`` and
declare parameter axes in their spec trees; the launcher activates a
``(mesh, rules)`` environment and everything resolves through it.  Outside
an environment every annotation is a no-op, so the same model code runs on
one CPU device (smoke tests) and on the 512-chip production mesh.

Robustness rule: a logical axis only shards if the dimension is divisible
by the product of mesh-axis sizes — otherwise it silently replicates (e.g.
8 Mixtral experts on a 16-way model axis, whisper's 8 heads).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "axis_env",
    "current_env",
    "logical_constraint",
    "sharding_for_spec",
    "tree_shardings",
    "make_rules",
]

# logical name -> mesh axis (or tuple of axes, or None = replicate)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "scenario": ("pod", "data"),  # batched-solver scenario axis (sweep copies)
    "seq": None,            # "model" enables sequence/context parallelism
    "kv_seq": None,         # "model" enables context-parallel decode
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "expert_ffn": "model",
    "experts": "model",
    "vocab": "model",
    "model": "model",       # identity for directly-annotated params
    "fsdp": "data",
}

_ENV: contextvars.ContextVar = contextvars.ContextVar("repro_axis_env", default=None)


def make_rules(cfg=None, **overrides) -> dict:
    """Per-arch rules: start from defaults, apply config knobs + overrides."""
    rules = dict(DEFAULT_RULES)
    if cfg is not None:
        if not cfg.attn_tp:
            rules["heads"] = None
            rules["kv_heads"] = None
        if getattr(cfg, "seq_shard", False):
            rules["seq"] = "model"   # sequence parallelism (§Perf h2b/h3d)
    rules.update(overrides)
    return rules


@contextlib.contextmanager
def axis_env(mesh: Mesh, rules: Optional[dict] = None):
    token = _ENV.set((mesh, rules or dict(DEFAULT_RULES)))
    try:
        yield
    finally:
        _ENV.reset(token)


def current_env():
    return _ENV.get()


def _resolve(name, dim: int, mesh: Mesh, rules: dict, used: set | None = None):
    """Logical name -> tuple of mesh axes (or None).

    Guards: (a) the dim must divide the mesh-axis product, (b) a mesh axis
    may appear only once per spec — first dim wins, later dims replicate
    (e.g. MoE weights where both 'experts' and 'expert_ffn' map to 'model')."""
    if name is None:
        return None
    ax = rules.get(name)
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    axes = tuple(a for a in axes if a in mesh.axis_names
                 and (used is None or a not in used))
    if not axes:
        return None
    size = math.prod(mesh.shape[a] for a in axes)
    if size == 0 or dim % size != 0:
        return None
    if used is not None:
        used.update(axes)
    return axes if len(axes) > 1 else axes[0]


def _resolve_spec(names, shape, mesh: Mesh, rules: dict):
    used: set = set()
    return [_resolve(nm, shape[i], mesh, rules, used) for i, nm in enumerate(names)]


def logical_constraint(x, *names):
    env = _ENV.get()
    if env is None:
        return x
    mesh, rules = env
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = _resolve_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def sharding_for_spec(shape, axes, mesh: Mesh, rules: dict,
                      fsdp: bool = False) -> NamedSharding:
    """Parameter sharding from a spec leaf; with ``fsdp`` the first
    replicated dim that divides the data axis additionally shards over it
    (ZeRO-3-style weight sharding)."""
    spec = _resolve_spec(axes, shape, mesh, rules)
    used = set()
    for s in spec:
        if s:
            used.update(s if isinstance(s, tuple) else (s,))
    if fsdp and "data" in mesh.axis_names and "data" not in used:
        dsize = mesh.shape["data"]
        for i, s in enumerate(spec):
            if s is None and shape[i] % dsize == 0 and shape[i] >= 512:
                spec[i] = "data"
                break
    return NamedSharding(mesh, P(*spec))


def tree_shardings(specs, mesh: Mesh, rules: dict, fsdp: bool = False):
    """Map a spec tree — leaves (shape, dtype, axes) — to NamedShardings."""

    def leaf(s):
        shape, _dtype, axes = s
        return sharding_for_spec(shape, axes, mesh, rules, fsdp)

    return jax.tree.map(leaf, specs, is_leaf=_is_spec_leaf)


def _is_spec_leaf(x):
    return (
        isinstance(x, tuple)
        and len(x) == 3
        and isinstance(x[0], tuple)
        and isinstance(x[1], str)
    )


def spec_struct(specs):
    """Spec tree -> ShapeDtypeStruct tree (dry-run lowering input)."""

    def leaf(s):
        shape, dtype, _axes = s
        return jax.ShapeDtypeStruct(shape, np.dtype(dtype) if dtype != "bfloat16"
                                    else jax.numpy.bfloat16)

    return jax.tree.map(leaf, specs, is_leaf=_is_spec_leaf)
