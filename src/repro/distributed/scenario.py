"""Scenario-axis sharding for the batched refactorize/solve engine.

A sweep batch (Monte-Carlo copies, corners, AC frequencies) is embarrassingly
parallel across scenarios: every batched kernel in the executor is a ``vmap``
over the leading axis and every per-matrix reduction (``a_max``, pivot
growth, backward error) stays within its own row.  ``ScenarioSharding``
captures how that leading axis maps onto a device mesh via the ``"scenario"``
entry of the logical-axis rules table (`sharding.DEFAULT_RULES`): value/rhs
batches shard along the resolved mesh axes, while plan metadata (indices,
scatter maps, bucket ladder) is replicated so each shard runs the full fused
schedule on its slice — the ONE-dispatch property holds per shard.

Resolution follows the same robustness rule as ``sharding._resolve``: axes
missing from the mesh or of size 1 drop out, and a mesh that resolves to a
single shard yields ``None`` (run unsharded — no shard_map overhead).
Batch-divisibility is handled one level up (the GLU facade pads the batch);
the runners themselves silently fall back to the unsharded executable when
handed a non-divisible batch, mirroring the silent-replicate rule.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import DEFAULT_RULES

__all__ = ["ScenarioSharding", "make_scenario_sharding", "make_sweep_mesh"]


@dataclasses.dataclass(frozen=True)
class ScenarioSharding:
    """A mesh plus the axes the scenario (batch) dimension shards over."""

    mesh: Mesh
    axes: tuple

    @property
    def n_shards(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.axes)

    @property
    def axis_names(self):
        """Axis-name form accepted by ``lax.psum`` etc."""
        return self.axes if len(self.axes) > 1 else self.axes[0]

    @property
    def spec(self) -> P:
        return P(self.axis_names)

    @property
    def batch_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec)

    @property
    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    @property
    def descriptor(self) -> tuple:
        """Hashable identity for ExecutableCache keys — sharded and
        unsharded runners (and runners on different meshes) never collide."""
        shape = tuple((a, int(s)) for a, s in self.mesh.shape.items())
        ids = tuple(int(d.id) for d in self.mesh.devices.flat)
        return (shape, self.axes, ids)

    def pad(self, batch: int) -> int:
        """Smallest multiple of ``n_shards`` >= batch."""
        k = self.n_shards
        return ((batch + k - 1) // k) * k

    def replicate(self, tree):
        """Place every array leaf of ``tree`` replicated on the mesh."""
        return jax.tree.map(
            lambda x: jax.device_put(x, self.replicated_sharding), tree)

    def shard_batch(self, x):
        """Place a leading-axis batch array sharded along the scenario axes."""
        return jax.device_put(x, self.batch_sharding)


def make_scenario_sharding(mesh: Optional[Mesh],
                           rules: Optional[dict] = None
                           ) -> Optional[ScenarioSharding]:
    """Resolve the ``"scenario"`` logical axis against ``mesh``.

    Returns ``None`` when no mesh is given or the resolved shard count is 1
    (callers treat that as "run unsharded").
    """
    if mesh is None:
        return None
    rules = rules if rules is not None else DEFAULT_RULES
    ax = rules.get("scenario")
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    axes = tuple(a for a in axes if a in mesh.axis_names and mesh.shape[a] > 1)
    if not axes:
        return None
    return ScenarioSharding(mesh=mesh, axes=axes)


def make_sweep_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """A 1-D ``("data",)`` mesh over the host's devices for scenario sweeps.

    On CPU, emulate a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
    initialises).
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"requested {n_devices} devices, only {len(devs)} available")
        devs = devs[:n_devices]
    return Mesh(np.array(devs), ("data",))
