"""Collective helpers: int8 gradient compression and explicit reductions.

``compressed_psum`` is the shard_map building block: quantise to int8 with a
per-tensor amax scale, all-reduce the small integers, dequantise.  TPU
all-reduce accumulates in the wire dtype, so the sum is carried in int32 to
avoid overflow across >127 shards — the wire volume is 4x smaller than f32
(1x of bf16); the fidelity loss is the quantisation itself.

``fake_quantize_grads`` applies the same quantisation *numerics* inside a
GSPMD-jitted step (where the all-reduce is implicit): it models the
accuracy effect of compression so experiments can measure convergence
impact without leaving the pjit world.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "fake_quantize_grads", "quantize_int8",
           "dequantize_int8", "psum_exact"]


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str):
    """int8-compressed psum over ``axis_name`` (use inside shard_map)."""

    def leaf(g):
        q, scale = quantize_int8(g.astype(jnp.float32))
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(scale, axis_name)
        return total.astype(jnp.float32) * smax

    return jax.tree.map(leaf, tree)


def psum_exact(tree, axis_name):
    """Uncompressed psum over ``axis_name`` (use inside shard_map).

    For small integer/scalar diagnostics — perturbation counts, ladder
    escalation tallies — where quantisation loss is unacceptable and the
    wire volume is a handful of scalars anyway.  ``axis_name`` may be a
    single name or a tuple of mesh axes."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def fake_quantize_grads(tree):
    def leaf(g):
        q, scale = quantize_int8(g.astype(jnp.float32))
        return dequantize_int8(q, scale).astype(g.dtype)

    return jax.tree.map(leaf, tree)
