"""Build the EXPERIMENTS.md roofline table from dry-run JSON records.

  PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def load(out_dir) -> list[dict]:
    recs = []
    for f in sorted(Path(out_dir).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("ok"):
            recs.append(r)
    return recs


def table(recs, mesh: str = "16x16", tags=("",)) -> str:
    rows = []
    header = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
              "| useful | roofline | HBM/dev GB |\n"
              "|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != mesh or r.get("tag", "") not in tags:
            continue
        x = r["roofline"]
        mem = (r["memory"]["temp_bytes_per_device"]
               + r["memory"]["argument_bytes_per_device"]
               + r["memory"].get("alias_bytes", 0) // r["chips"]) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {x['compute_s']:.3g} | "
            f"{x['memory_s']:.3g} | {x['collective_s']:.3g} | {x['dominant']} | "
            f"{x['useful_fraction']:.2f} | {x['roofline_fraction']:.3f} | "
            f"{mem:.2f} |")
    return "\n".join([header] + rows)


def worst_cells(recs, mesh="16x16", k=5):
    cells = [r for r in recs if r["mesh"] == mesh and not r.get("tag")]
    cells.sort(key=lambda r: r["roofline"]["roofline_fraction"])
    return [(r["arch"], r["shape"], r["roofline"]["roofline_fraction"],
             r["roofline"]["dominant"]) for r in cells[:k]]


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(out_dir)
    for mesh in ("16x16", "2x16x16"):
        n = sum(r["mesh"] == mesh for r in recs)
        print(f"\n## mesh {mesh} ({n} cells)\n")
        print(table(recs, mesh))
    print("\nworst roofline fractions (16x16):")
    for arch, shape, frac, dom in worst_cells(recs):
        print(f"  {arch} {shape}: {frac:.3f} ({dom}-bound)")
    doms = {}
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    print("\ndominant-term histogram:", doms)


if __name__ == "__main__":
    main()
