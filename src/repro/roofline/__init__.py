from .analysis import Roofline, analyze, collective_bytes_from_hlo, model_flops_for

__all__ = ["Roofline", "analyze", "collective_bytes_from_hlo", "model_flops_for"]
