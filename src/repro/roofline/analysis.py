"""Three-term roofline analysis from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory term     = HLO_bytes / (chips * HBM_BW)
  collective term = collective_bytes / (chips * LINK_BW)

``compiled.cost_analysis()`` runs on the SPMD-*partitioned* per-device
module, so its flops/bytes are already per-device: the formulas above are
evaluated with global values = per_device * chips, which cancels the chips
factor.  Collective bytes are parsed from the partitioned HLO text (sum of
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute) and are likewise per-device.

Caveat recorded in EXPERIMENTS.md: the CPU-backend HLO cost model is
fusion-blind, so HLO_bytes over-counts intermediate traffic relative to a
fused TPU executable — the memory term is an upper bound; deltas between
configurations remain meaningful.

Hardware model (TPU v5e-class, per the brief):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes, summed over ops (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # result shape is on the LHS: "%name = <shape(s)> opcode(...)"
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        op = None
        rhs_head = rhs.lstrip()
        for k in _COLLECTIVES:
            if rhs_head.startswith(k) or f" {k}(" in rhs_head[:160] or rhs_head.startswith(f"({k}"):
                op = k
                break
            # "%x = f32[..] all-reduce(...)" — opcode appears after shapes
            m = re.match(r"^[^(]*?\b" + k + r"\b", rhs_head.split("(")[0]) if "(" in rhs_head else None
            if m:
                op = k
                break
        if op is None:
            continue
        shapes_part = rhs_head.split(op)[0]
        nbytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(shapes_part))
        if nbytes == 0:
            continue
        out[op] += nbytes
        counts[op] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device (partitioned module)
    hlo_bytes: float             # per device
    collective_bytes: float      # per device
    collective_detail: dict
    model_flops: float           # 6*N*D (or 6*N_active*D) useful flops, global
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self) -> "Roofline":
        # global = per_device * chips; the chips factor in the brief's
        # denominators cancels against it
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes / LINK_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flops / (bound_time * peak compute)."""
        denom = self.bound_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "collective_detail": self.collective_detail,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, n_active: Optional[int] = None) -> float:
    """6*N*D for train, 2*N*D for inference (per forward); D = tokens."""
    n = n_active if n_active is not None else cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_tok = 6 * n if shape.kind == "train" else 2 * n
    return float(per_tok) * tokens


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float) -> Roofline:
    col = collective_bytes_from_hlo(hlo_text)
    detail = {k: v for k, v in col.items() if k != "_counts"}
    total_col = sum(detail.values())
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=float(total_col),
        collective_detail={**detail, "counts": col.get("_counts", {})},
        model_flops=model_flops,
    ).finalize()
