"""Config-driven model assembly: spec trees, init, and the three entry
points (``forward_train``, ``forward_prefill``, ``forward_decode``) shared
by all 10 assigned architectures.

Layer stacking: architectures are built from *pattern groups* — a periodic
layer pattern (period = lcm(attn_every, moe_every)) repeated R times.  When
``scan_layers`` is enabled (default for deep configs), each group's
parameters are stacked with a leading R dim and executed under
``jax.lax.scan``: the HLO contains ONE copy of the pattern instead of L,
which cuts compile time ~L-fold (the standard MaxText/Megatron-JAX trick)
while keeping per-layer semantics identical (validated in tests against the
unscanned path).

Block layout per layer i:
  mixer: attention (full/swa/mla) if cfg.is_attn_layer(i) else mamba2
  ffn:   MoE if cfg.is_moe_layer(i) else dense MLP (absent when d_ff == 0)
Encoder-decoder (whisper) adds an encoder stack + cross-attention and is
never scanned (6 layers).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from . import layers as L

Params = dict


# ---------------------------------------------------------------------------
# pattern groups
# ---------------------------------------------------------------------------

def _lcm(a, b):
    return a * b // math.gcd(a, b)


def use_scan(cfg) -> bool:
    return (
        getattr(cfg, "scan_layers", True)
        and cfg.encoder_layers == 0
        and cfg.num_layers >= 8
    )


def layer_groups(cfg) -> list[dict]:
    """[{start, indices | (repeat, period)} ...] covering all layers."""
    Lr = cfg.num_layers
    if not use_scan(cfg):
        return [{"start": 0, "scan": False, "indices": list(range(Lr))}]
    period = 1
    if cfg.attn_every:
        period = _lcm(period, cfg.attn_every)
    if cfg.n_experts and cfg.moe_every > 1:
        period = _lcm(period, cfg.moe_every)
    start = cfg.first_dense
    body = Lr - start
    repeat = body // period
    rem_start = start + repeat * period
    groups: list[dict] = []
    if start:
        groups.append({"start": 0, "scan": False, "indices": list(range(start))})
    if repeat >= 2:
        groups.append({"start": start, "scan": True, "repeat": repeat, "period": period})
    else:
        groups.append({"start": start, "scan": False,
                       "indices": list(range(start, rem_start))})
    if rem_start < Lr:
        groups.append({"start": rem_start, "scan": False,
                       "indices": list(range(rem_start, Lr))})
    return groups


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _layer_specs(cfg, i: int) -> Params:
    p: Params = {"norm1": L.norm_specs(cfg, cfg.d_model)}
    if cfg.is_attn_layer(i):
        p["attn"] = L.mla_specs(cfg) if cfg.attention == "mla" else L.attention_specs(cfg)
    else:
        p["mamba"] = L.mamba2_specs(cfg)
    if cfg.encoder_layers:
        p["norm_x"] = L.norm_specs(cfg, cfg.d_model)
        p["cross"] = L.cross_attention_specs(cfg)
    if cfg.d_ff or cfg.is_moe_layer(i):
        p["norm2"] = L.norm_specs(cfg, cfg.d_model)
        p["ffn"] = L.moe_specs(cfg) if cfg.is_moe_layer(i) else L.mlp_specs(cfg)
    return p


_SPEC = lambda x: (  # noqa: E731
    isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple) and isinstance(x[1], str)
)


def _stack_specs(spec_tree, repeat: int):
    def leaf(s):
        shape, dtype, axes = s
        return ((repeat, *shape), dtype, (None, *axes))

    return jax.tree.map(leaf, spec_tree, is_leaf=_SPEC)


def _group_specs(cfg, g: dict):
    if not g["scan"]:
        return {"layers": [_layer_specs(cfg, i) for i in g["indices"]]}
    return {
        "pattern": [
            _stack_specs(_layer_specs(cfg, g["start"] + pos), g["repeat"])
            for pos in range(g["period"])
        ]
    }


def param_specs(cfg) -> Params:
    V, d = cfg.padded_vocab, cfg.d_model
    dt = cfg.dtype
    specs: Params = {
        "embed": ((V, d), dt, ("vocab", None)),
        "blocks": [_group_specs(cfg, g) for g in layer_groups(cfg)],
        "final_norm": L.norm_specs(cfg, d),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ((d, V), dt, (None, "vocab"))
    if cfg.encoder_layers:
        specs["encoder"] = {
            "layers": [
                {
                    "norm1": L.norm_specs(cfg, d),
                    "attn": L.attention_specs(cfg),
                    "norm2": L.norm_specs(cfg, d),
                    "ffn": L.mlp_specs(cfg),
                }
                for _ in range(cfg.encoder_layers)
            ],
            "final_norm": L.norm_specs(cfg, d),
        }
    if cfg.frontend == "vision_stub":
        specs["patch_proj"] = ((d, d), dt, (None, None))
    return specs


def init_params(cfg, key) -> Params:
    """Materialise parameters (smoke tests / real training of small models)."""
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_SPEC)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, (shape, dtype, axes) in zip(keys, leaves):
        jdt = jnp.bfloat16 if dtype == "bfloat16" else jnp.dtype(dtype)
        if len(shape) == 1:
            out.append(jnp.zeros(shape, jdt))
            continue
        fan_in = shape[-2] if len(shape) >= 2 else 1
        std = min(0.02, 1.0 / math.sqrt(max(fan_in, 1)))
        out.append((jax.random.normal(k, shape, jnp.float32) * std).astype(jdt))
    params = jax.tree.unflatten(treedef, out)

    def fix(path, x):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name.endswith("scale"):
            return jnp.ones_like(x)
        if name.endswith("A_log"):
            lin = jnp.log(jnp.linspace(1.0, 16.0, x.shape[-1], dtype=jnp.float32))
            return jnp.broadcast_to(lin, x.shape)
        if name.endswith("/D"):
            return jnp.ones_like(x)
        if name.endswith("dt_bias"):
            return jnp.full_like(x, 0.5)
        return x

    return jax.tree_util.tree_map_with_path(fix, params)


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------

def _sinusoidal(positions, d, dtype):
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _embed(params, tokens, cfg, extras) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    # patch prefix only applies to full-sequence passes, never decode steps
    if (cfg.frontend == "vision_stub" and extras and "patch_embeds" in extras
            and x.shape[1] > 1):
        pe = jnp.einsum("bnd,de->bne", extras["patch_embeds"].astype(x.dtype),
                        params["patch_proj"])
        n = pe.shape[1]
        if n >= x.shape[1]:
            x = pe[:, : x.shape[1]]
        else:
            x = jnp.concatenate([pe, x[:, n:]], axis=1)
    return lc(x, "batch", "seq", None)


def _encode(params, frames, cfg) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    ep = params["encoder"]
    B, S, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else frames.dtype)
    x = x + _sinusoidal(pos, cfg.d_model, x.dtype)
    for lp in ep["layers"]:
        h = L.apply_norm(lp["norm1"], x, cfg.norm)
        a, _ = L.attention(lp["attn"], h, cfg, positions=pos, mode="bidir")
        x = x + a
        h = L.apply_norm(lp["norm2"], x, cfg.norm)
        x = x + L.mlp(lp["ffn"], h, cfg.act)
    return L.apply_norm(ep["final_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# one decoder layer
# ---------------------------------------------------------------------------

def _layer(lp, x, cfg, i, *, positions, mode, cache, enc_kv_i, aux):
    h = L.apply_norm(lp["norm1"], x, cfg.norm)
    if cfg.is_attn_layer(i):
        if cfg.attention == "mla":
            a, new_cache = L.mla_attention(lp["attn"], h, cfg, positions=positions,
                                           mode=mode, cache=cache)
        else:
            a, new_cache = L.attention(lp["attn"], h, cfg, positions=positions,
                                       mode=mode, cache=cache)
    else:
        a, new_cache = L.mamba2_block(lp["mamba"], h, cfg,
                                      mode="decode" if mode == "decode" else "causal",
                                      cache=cache)
    x = x + a
    if "cross" in lp and enc_kv_i is not None:
        h = L.apply_norm(lp["norm_x"], x, cfg.norm)
        x = x + L.cross_attention(lp["cross"], h, enc_kv_i, cfg)
    if "ffn" in lp:
        h = L.apply_norm(lp["norm2"], x, cfg.norm)
        if cfg.is_moe_layer(i):
            f, a2 = L.moe(lp["ffn"], h, cfg)
            aux = aux + a2
        else:
            f = L.mlp(lp["ffn"], h, cfg.act)
        x = x + f
    return lc(x, "batch", "seq", None), new_cache, aux


def _remat_wrap(fn, cfg):
    """Apply the configured remat policy: 'full' saves nothing (recompute
    everything in backward); 'dots' saves matmul outputs (selective remat —
    recompute only cheap elementwise/norm ops)."""
    if not cfg.remat:
        return fn
    if getattr(cfg, "remat_policy", "full") == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _maybe_remat(fn, cfg):
    return _remat_wrap(fn, cfg)


# ---------------------------------------------------------------------------
# running all layers (scan-aware)
# ---------------------------------------------------------------------------

def _empty_cache_like(cfg, i):
    """Structure placeholder for prefill cache collection."""
    return None


def _apply_blocks(params, x, cfg, *, positions, mode, cache, enc_kv, aux,
                  collect_cache: bool):
    """Runs every layer; returns (x, new_cache_blocks, aux)."""
    groups = layer_groups(cfg)
    new_blocks = []
    for gi, g in enumerate(groups):
        gp = params["blocks"][gi]
        gcache = cache[gi] if cache is not None else None
        if not g["scan"]:
            outs = []
            for li, i in enumerate(g["indices"]):
                lcache = gcache["layers"][li] if gcache is not None else None

                def one(x_, aux_, lcache_, lp=gp if False else None, li=li, i=i):
                    return _layer(gp["layers"][li], x_, cfg, i, positions=positions,
                                  mode=mode, cache=lcache_,
                                  enc_kv_i=enc_kv[i] if enc_kv else None, aux=aux_)

                if cfg.remat and mode == "causal" and not collect_cache:
                    def body(x_, aux_, li=li, i=i):
                        y, _, a = _layer(gp["layers"][li], x_, cfg, i,
                                         positions=positions, mode=mode, cache=None,
                                         enc_kv_i=enc_kv[i] if enc_kv else None,
                                         aux=aux_)
                        return y, a

                    x, aux = _remat_wrap(body, cfg)(x, aux)
                    outs.append(None)
                else:
                    x, c, aux = one(x, aux, lcache)
                    outs.append(c)
            new_blocks.append({"layers": outs})
        else:
            period, repeat = g["period"], g["repeat"]
            start = g["start"]

            def scan_body(carry, xs, start=start, period=period, gi=gi):
                x_, aux_ = carry
                pat_params, pat_cache = xs
                new_pat_cache = []
                for pos in range(period):
                    i = start + pos  # kind is periodic; representative index
                    x_, c, aux_ = _layer(
                        pat_params[pos], x_, cfg, i, positions=positions,
                        mode=mode,
                        cache=pat_cache[pos] if pat_cache is not None else None,
                        enc_kv_i=None, aux=aux_)
                    new_pat_cache.append(c)
                if any(c is not None for c in new_pat_cache):
                    return (x_, aux_), new_pat_cache
                return (x_, aux_), None

            body = _remat_wrap(scan_body, cfg)
            pat_params = gp["pattern"]
            pat_cache = gcache["pattern"] if gcache is not None else None
            want_cache = collect_cache or mode == "decode"
            if not want_cache and pat_cache is None:
                # training: no cache threading at all
                def scan_body_nc(carry, pat_params_slice, start=start, period=period):
                    x_, aux_ = carry
                    for pos in range(period):
                        i = start + pos
                        x_, _, aux_ = _layer(pat_params_slice[pos], x_, cfg, i,
                                             positions=positions, mode=mode,
                                             cache=None, enc_kv_i=None, aux=aux_)
                    return (x_, aux_), None

                b = _remat_wrap(scan_body_nc, cfg)
                (x, aux), _ = jax.lax.scan(b, (x, aux), pat_params)
                new_blocks.append(None)
            else:
                (x, aux), ys = jax.lax.scan(body, (x, aux), (pat_params, pat_cache))
                new_blocks.append({"pattern": ys})
    return x, new_blocks, aux


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _prepare_encdec(params, positions, x, cfg, extras):
    if not cfg.encoder_layers:
        return x, None
    x = x + _sinusoidal(positions, cfg.d_model, x.dtype)
    enc_out = _encode(params, extras["frames"], cfg)
    groups = layer_groups(cfg)
    assert not any(g["scan"] for g in groups)
    enc_kv = []
    for g in groups:
        for li, i in enumerate(g["indices"]):
            enc_kv.append(L.encode_cross_kv(
                params["blocks"][0]["layers"][li]["cross"], enc_out))
    return x, enc_kv


def lm_head_of(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward_train(params, tokens, cfg, extras: Optional[dict] = None,
                  return_hidden: bool = False):
    """tokens (B,S) -> logits (B,S,V) float32 (or hidden states when
    ``return_hidden``); also returns the MoE aux loss."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = _embed(params, tokens, cfg, extras)
    x, enc_kv = _prepare_encdec(params, positions, x, cfg, extras)
    aux = jnp.zeros((), jnp.float32)
    x, _, aux = _apply_blocks(params, x, cfg, positions=positions, mode="causal",
                              cache=None, enc_kv=enc_kv, aux=aux,
                              collect_cache=False)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, aux
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head_of(params, cfg)).astype(jnp.float32)
    return lc(logits, "batch", "seq", "vocab"), aux


def _pad_cache_seq(cache_blocks, max_len: int):
    """Grow attention cache buffers to ``max_len`` slots (seq axis)."""

    def pad(c, seq_axis):
        out = dict(c)
        for key in ("k", "v", "ckv", "krope"):
            if key in out:
                arr = out[key]
                S = arr.shape[seq_axis]
                if S < max_len:
                    pads = [(0, 0)] * arr.ndim
                    pads[seq_axis] = (0, max_len - S)
                    out[key] = jnp.pad(arr, pads)
        return out

    new = []
    for b in cache_blocks:
        if b is None:
            new.append(None)
        elif "layers" in b:
            new.append({"layers": [pad(c, 1) if c else c for c in b["layers"]]})
        else:
            new.append({"pattern": [pad(c, 2) if c else c for c in b["pattern"]]})
    return new


def forward_prefill(params, tokens, cfg, extras: Optional[dict] = None,
                    max_len: Optional[int] = None):
    """Returns (last-token logits (B,V), cache pytree)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = _embed(params, tokens, cfg, extras)
    x, enc_kv = _prepare_encdec(params, positions, x, cfg, extras)
    aux = jnp.zeros((), jnp.float32)
    x, blocks, aux = _apply_blocks(params, x, cfg, positions=positions,
                                   mode="causal", cache=None, enc_kv=enc_kv,
                                   aux=aux, collect_cache=True)
    x = L.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head_of(params, cfg)
                        ).astype(jnp.float32)[:, 0]
    if max_len is not None and max_len > S and cfg.attention != "swa":
        blocks = _pad_cache_seq(blocks, max_len)
    return logits, {"blocks": blocks, "enc_kv": enc_kv, "pos": jnp.int32(S)}


def forward_decode(params, token, cache, cfg, extras: Optional[dict] = None):
    """token (B,1) + cache -> (logits (B,V), new cache). One decode step."""
    B = token.shape[0]
    idx = cache["pos"]
    positions = jnp.broadcast_to(idx[None, None] if jnp.ndim(idx) == 0 else idx,
                                 (B, 1)).astype(jnp.int32)
    x = _embed(params, token, cfg, extras)
    if cfg.encoder_layers:
        x = x + _sinusoidal(positions, cfg.d_model, x.dtype)
    aux = jnp.zeros((), jnp.float32)
    x, blocks, aux = _apply_blocks(params, x, cfg, positions=positions,
                                   mode="decode", cache=cache["blocks"],
                                   enc_kv=cache.get("enc_kv"), aux=aux,
                                   collect_cache=True)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head_of(params, cfg)
                        ).astype(jnp.float32)[:, 0]
    return logits, {"blocks": blocks, "enc_kv": cache.get("enc_kv"),
                    "pos": idx + 1}


# ---------------------------------------------------------------------------
# cache specs (dry-run serve_step inputs)
# ---------------------------------------------------------------------------

def _layer_cache_specs(cfg, i: int, batch: int, seq_len: int):
    dt = cfg.dtype
    if cfg.is_attn_layer(i):
        if cfg.attention == "mla":
            return {
                "ckv": ((batch, seq_len, cfg.kv_lora_rank), dt,
                        ("batch", "kv_seq", None)),
                "krope": ((batch, seq_len, cfg.qk_rope_head_dim), dt,
                          ("batch", "kv_seq", None)),
                "index": ((), "int32", ()),
            }
        S = min(seq_len, cfg.window) if cfg.attention == "swa" else seq_len
        return {
            "k": ((batch, S, cfg.num_kv_heads, cfg.hd), dt,
                  ("batch", "kv_seq", "kv_heads", None)),
            "v": ((batch, S, cfg.num_kv_heads, cfg.hd), dt,
                  ("batch", "kv_seq", "kv_heads", None)),
            "index": ((), "int32", ()),
        }
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    conv_dim = di + 2 * cfg.ssm_state
    return {
        "h": ((batch, H, cfg.ssm_head_dim, cfg.ssm_state), "float32",
              ("batch", "heads", None, None)),
        "conv": ((batch, cfg.ssm_conv - 1, conv_dim), dt,
                 ("batch", None, "ffn")),
    }


def cache_specs(cfg, batch: int, seq_len: int) -> Any:
    """Spec tree for a cache holding ``seq_len`` tokens (decode dry-run)."""
    blocks = []
    for g in layer_groups(cfg):
        if not g["scan"]:
            blocks.append({"layers": [
                _layer_cache_specs(cfg, i, batch, seq_len) for i in g["indices"]
            ]})
        else:
            blocks.append({"pattern": [
                _stack_specs(_layer_cache_specs(cfg, g["start"] + pos, batch, seq_len),
                             g["repeat"])
                for pos in range(g["period"])
            ]})
    out = {"blocks": blocks, "pos": ((), "int32", ())}
    if cfg.encoder_layers:
        out["enc_kv"] = [
            (((batch, cfg.encoder_seq, cfg.num_heads, cfg.hd), cfg.dtype,
              ("batch", None, "heads", None)),
             ((batch, cfg.encoder_seq, cfg.num_heads, cfg.hd), cfg.dtype,
              ("batch", None, "heads", None)))
            for _ in range(cfg.num_layers)
        ]
    else:
        out["enc_kv"] = None
    return out
