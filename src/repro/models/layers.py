"""Pure-JAX model layers: norms, rotary, GQA/SWA/MLA attention, MLPs,
sort-based MoE, and the Mamba-2 SSD block.

Conventions
-----------
* Parameters are nested dicts of arrays.  Each layer has a ``*_specs``
  builder returning the same tree with ``(shape, dtype, logical_axes)``
  leaves — the dry-run lowers from specs without allocating.
* Activations are annotated with logical sharding axes via
  ``repro.distributed.sharding.logical_constraint`` (no-op outside a mesh).
* Compute dtype follows the input; softmax/normalisation run in float32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc

Params = dict
Spec = tuple  # (shape, dtype, logical_axes)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------

def norm_specs(cfg, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": ((d,), "float32", (None,)), "bias": ((d,), "float32", (None,))}
    return {"scale": ((d,), "float32", (None,))}


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf**2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (with partial-rotary support)
# ---------------------------------------------------------------------------

def apply_rotary(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                 rotary_dim: Optional[int] = None) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    if rd == 0:
        return x
    half = rd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., :half], xr[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot, xp], axis=-1) if rd < hd else rot


# ---------------------------------------------------------------------------
# attention (GQA full / sliding-window) with optional KV cache
# ---------------------------------------------------------------------------

def _dus_seq(buf, val, idx):
    """dynamic_update_slice at (0, idx, 0, ...) with uniform int32 indices
    (mixed int widths are an error under jax_enable_x64)."""
    import jax.numpy as _jnp

    zeros = tuple(_jnp.zeros((), _jnp.int32) for _ in range(buf.ndim - 2))
    start = (_jnp.zeros((), _jnp.int32), idx.astype(_jnp.int32)) + zeros
    return jax.lax.dynamic_update_slice(buf, val, start)


def attention_specs(cfg) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = cfg.dtype
    h_ax = "model" if cfg.attn_tp else None
    kv_ax = "model" if (cfg.attn_tp and KV % 16 == 0) else None
    p = {
        "wq": ((d, H, hd), dt, (None, h_ax, None)),
        "wk": ((d, KV, hd), dt, (None, kv_ax, None)),
        "wv": ((d, KV, hd), dt, (None, kv_ax, None)),
        "wo": ((H, hd, d), dt, (h_ax, None, None)),
    }
    if cfg.qkv_bias:
        p["bq"] = ((H, hd), dt, (h_ax, None))
        p["bk"] = ((KV, hd), dt, (kv_ax, None))
        p["bv"] = ((KV, hd), dt, (kv_ax, None))
    return p


def _sdpa(q, k, v, mask, H_per_kv):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd).

    mask must be broadcastable to (B, KV, g, Sq, Sk) — callers pass
    (1, 1, 1, Sq, Sk) for causal/bidir or (B, 1, 1, 1, Sk) for decode.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    q = q.reshape(B, Sq, KV, H_per_kv, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) / math.sqrt(hd)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def attention(p: Params, x: jnp.ndarray, cfg, *, positions: jnp.ndarray,
              mode: str = "causal", cache: Optional[dict] = None) -> tuple:
    """Returns (out, new_cache).

    mode: "causal" | "bidir" (encoder) | "decode" (single step w/ cache)
    For cfg.attention == "swa" a band mask / rolling-buffer cache is used.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    rd = int(cfg.rotary_pct * hd) if cfg.rotary_pct < 1.0 else hd

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = lc(q, "batch", "seq", "heads", None)
    k = lc(k, "batch", "seq", "kv_heads", None)
    if mode != "bidir":
        q = apply_rotary(q, positions, cfg.rope_theta, rd)
        k = apply_rotary(k, positions, cfg.rope_theta, rd)

    window = cfg.window if cfg.attention == "swa" else 0

    if mode == "decode":
        assert cache is not None and S == 1
        idx = cache["index"]
        if window:
            slot = idx % window
            ck = _dus_seq(cache["k"], k, slot)
            cv = _dus_seq(cache["v"], v, slot)
            Smax = ck.shape[1]
            valid = jnp.arange(Smax)[None, :] < jnp.minimum(idx + 1, Smax)
        else:
            ck = _dus_seq(cache["k"], k, idx)
            cv = _dus_seq(cache["v"], v, idx)
            Smax = ck.shape[1]
            valid = jnp.arange(Smax)[None, :] <= idx
        mask = valid[:, None, None, None, :]        # (1,1,1,1,Smax)
        out = _sdpa(q, ck, cv, mask, H // KV)
        new_cache = {"k": ck, "v": cv, "index": idx + 1}
    else:
        if mode == "causal":
            i = jnp.arange(S)[:, None]
            j = jnp.arange(S)[None, :]
            mask = j <= i
            if window:
                mask &= (i - j) < window
        else:
            mask = jnp.ones((S, S), dtype=bool)
        out = _sdpa(q, k, v, mask[None, None, None], H // KV)
        if mode == "causal":
            if window:
                # rolling buffer holding the trailing ``window`` positions;
                # slot layout: position p lives at p % window
                if S <= window:
                    pad = window - S
                    tail_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    tail_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                else:
                    tail_k = k[:, S - window:]
                    tail_v = v[:, S - window:]
                roll = S % window if S > window else 0
                tail_k = jnp.roll(tail_k, roll, axis=1)
                tail_v = jnp.roll(tail_v, roll, axis=1)
                new_cache = {"k": tail_k, "v": tail_v, "index": jnp.int32(S)}
            else:
                new_cache = {"k": k, "v": v, "index": jnp.int32(S)}
        else:
            new_cache = None

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return lc(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention_specs(cfg) -> Params:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    dt = cfg.dtype
    h_ax = "model" if cfg.attn_tp else None
    return {
        "wq": ((d, H, hd), dt, (None, h_ax, None)),
        "wk": ((d, H, hd), dt, (None, h_ax, None)),
        "wv": ((d, H, hd), dt, (None, h_ax, None)),
        "wo": ((H, hd, d), dt, (h_ax, None, None)),
    }


def cross_attention(p: Params, x: jnp.ndarray, enc_kv: tuple, cfg) -> jnp.ndarray:
    """enc_kv = (k, v) precomputed from encoder output: (B, Senc, H, hd)."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = _sdpa(q, k, v, jnp.ones((1, 1, 1, 1, 1), dtype=bool), 1)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p: Params, enc_out: jnp.ndarray) -> tuple:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_specs(cfg) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = cfg.dtype
    return {
        "wq": ((d, H, dn + dr), dt, (None, "model", None)),
        "w_dkv": ((d, r + dr), dt, (None, None)),        # down: c_kv + shared k_rope
        "kv_norm": {"scale": ((r,), "float32", (None,))},
        "w_uk": ((r, H, dn), dt, (None, "model", None)),  # up: k_nope
        "w_uv": ((r, H, dv), dt, (None, "model", None)),  # up: v
        "wo": ((H, dv, d), dt, ("model", None, None)),
    }


def mla_attention(p: Params, x: jnp.ndarray, cfg, *, positions, mode="causal",
                  cache: Optional[dict] = None) -> tuple:
    B, S, d = x.shape
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rotary(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv, k_rope = dkv[..., :r], dkv[..., r:]
    c_kv = apply_norm(p["kv_norm"], c_kv, "rmsnorm")
    k_rope = apply_rotary(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if mode == "decode":
        assert cache is not None and S == 1
        idx = cache["index"]
        c_kv = _dus_seq(cache["ckv"], c_kv, idx)
        k_rope = _dus_seq(cache["krope"], k_rope, idx)
        Sk = c_kv.shape[1]
        valid = jnp.arange(Sk)[None, :] <= idx            # (1,Sk)
        mask = valid[None, None]
        new_cache = {"ckv": c_kv, "krope": k_rope, "index": idx + 1}
    else:
        Sk = S
        i = jnp.arange(S)[:, None]
        mask = (jnp.arange(S)[None, :] <= i)[None, None]
        new_cache = {"ckv": c_kv, "krope": k_rope, "index": jnp.int32(S)} \
            if mode == "causal" else None

    # expand the compressed cache (decode recomputes k/v from latents — the
    # MLA memory/compute trade)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])

    scale = 1.0 / math.sqrt(dn + dr)
    scores = (
        jnp.einsum("bqhk,bshk->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope)
    ) * scale
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", w, v)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return lc(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.dtype
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": ((d, f), dt, (None, "ffn")),
            "w_up": ((d, f), dt, (None, "ffn")),
            "w_down": ((f, d), dt, ("ffn", None)),
        }
    return {
        "w_up": ((d, f), dt, (None, "ffn")),
        "w_down": ((f, d), dt, ("ffn", None)),
    }


def mlp(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        if act == "gelu":
            h = jax.nn.gelu(h)
        elif act == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:
            raise ValueError(act)
    h = lc(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# MoE (sort-based capacity dispatch; EP over the model axis)
# ---------------------------------------------------------------------------

def moe_specs(cfg) -> Params:
    d, f, E = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    dt = cfg.dtype
    e_ax = "experts"  # mapped to model axis when E % tp == 0, else None
    p = {
        "router": ((d, E), "float32", (None, None)),
        "experts": {
            "w_gate": ((E, d, f), dt, (e_ax, None, "expert_ffn")),
            "w_up": ((E, d, f), dt, (e_ax, None, "expert_ffn")),
            "w_down": ((E, f, d), dt, (e_ax, "expert_ffn", None)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs(cfg, cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff))
    return p


def _moe_one_group(xf: jnp.ndarray, p: Params, cfg, cap: int):
    """Sort-based capacity dispatch for ONE token group (N_loc, D)."""
    N, D = xf.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                                # (N,K)
    gates = top_p / (top_p.sum(-1, keepdims=True) + 1e-9)

    # aux load-balancing loss (Switch-style)
    me = probs.mean(0)                               # mean router prob per expert
    ce = jnp.zeros(E).at[top_i.reshape(-1)].add(1.0) / (N * K)
    aux = E * jnp.sum(me * ce)

    eid = top_i.reshape(-1)                          # (N*K,)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    # rank within expert group
    pos_in_e = jnp.arange(N * K) - jnp.searchsorted(eid_s, eid_s, side="left")
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid_s * cap + pos_in_e, E * cap)  # overflow -> dropped
    token = order // K

    xe = jnp.zeros((E * cap, D), xf.dtype).at[slot].set(xf[token], mode="drop")
    xe = xe.reshape(E, cap, D)

    w = p["experts"]
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, w["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", xe, w["w_up"])
        h = (jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)) * u
    else:
        h = jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, w["w_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, w["w_down"]).reshape(E * cap, D)

    contrib = ye.at[slot].get(mode="fill", fill_value=0.0)
    gates_s = gates.reshape(-1)[order].astype(xf.dtype)
    out = jnp.zeros((N, D), xf.dtype).at[token].add(
        contrib * gates_s[:, None] * keep[:, None].astype(xf.dtype))
    return out, aux


def moe(p: Params, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss).

    ``cfg.moe_groups`` > 1 enables GShard-style *local dispatch groups*: the
    token stream splits into G groups (aligned with the data shards), each
    group routes/sorts/drops independently with capacity ceil(N/G·K/E·cf).
    The argsort and the dispatch scatter then never cross shard boundaries —
    only the expert einsums touch the model axis (see §Perf h1d).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, D)

    G = getattr(cfg, "moe_groups", 0) or 1
    if N % G != 0:
        G = 1
    n_loc = N // G
    cap = int(math.ceil(n_loc * K / E * cfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)

    if G > 1:
        xg = lc(xf.reshape(G, n_loc, D), "batch", None, None)
        out, aux = jax.vmap(lambda xx: _moe_one_group(xx, p, cfg, cap))(xg)
        out = out.reshape(N, D)
        aux = aux.mean()
    else:
        out, aux = _moe_one_group(xf, p, cfg, cap)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf[None], cfg.act)[0]
    return out.reshape(B, S, D), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def mamba2_specs(cfg) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = di // cfg.ssm_head_dim
    N = cfg.ssm_state
    dt = cfg.dtype
    conv_dim = di + 2 * N
    return {
        "in_proj": ((d, 2 * di + 2 * N + H), dt, (None, "ffn")),
        "conv_w": ((cfg.ssm_conv, conv_dim), dt, (None, "ffn")),
        "conv_b": ((conv_dim,), dt, ("ffn",)),
        "A_log": ((H,), "float32", (None,)),
        "D": ((H,), "float32", (None,)),
        "dt_bias": ((H,), "float32", (None,)),
        "out_norm": {"scale": ((di,), "float32", (None,))},
        "out_proj": ((di, d), dt, ("ffn", None)),
    }


def _ssd_chunked(xh, dt_h, A, B_s, C_s, chunk: int, h0=None):
    """Chunked SSD scan (Mamba-2 Alg. state-space dual form).

    xh:  (B,S,H,P) inputs,   dt_h: (B,S,H) positive step sizes
    A:   (H,) negative,      B_s/C_s: (B,S,N) (single group)
    h0:  optional initial state (B,H,P,N)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bb, S, H, P = xh.shape
    N = B_s.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xc = xh.reshape(Bb, nc, Q, H, P)
    dtc = dt_h.reshape(Bb, nc, Q, H)
    Bc = B_s.reshape(Bb, nc, Q, N)
    Cc = C_s.reshape(Bb, nc, Q, N)

    la = dtc * A            # (B,nc,Q,H), negative
    cs = jnp.cumsum(la, axis=2)                     # inclusive cumsum
    seg_total = cs[:, :, -1, :]                     # (B,nc,H)

    # --- intra-chunk (diagonal blocks) --------------------------------------
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)      # (B,nc,Q,Q)
    tri = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    expo = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # (B,nc,Q,Q,H)
    expo = jnp.where(tri[None, None, :, :, None], expo, -jnp.inf)  # mask BEFORE exp
    decay = jnp.exp(expo)
    scores = cb[..., None] * decay * dtc[:, :, None, :, :]        # weight by dt_s
    y = jnp.einsum("bctsh,bcshp->bcthp", scores, xc)

    # --- chunk summary states ----------------------------------------------
    dec_end = jnp.exp(seg_total[:, :, None, :] - cs)          # (B,nc,Q,H)
    sb = jnp.einsum("bcsh,bcsn,bcshp->bchpn", dtc * dec_end, Bc, xc)

    # --- inter-chunk recurrence ----------------------------------------------
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), sb.dtype)

    def scan_fn(h, inp):
        s_k, g_k = inp                               # (B,H,P,N), (B,H)
        h_prev = h
        h = h * jnp.exp(g_k)[:, :, None, None] + s_k
        return h, h_prev

    sb_t = jnp.moveaxis(sb, 1, 0)                    # (nc,B,H,P,N)
    g_t = jnp.moveaxis(seg_total, 1, 0)              # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(scan_fn, h0, (sb_t, g_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # (B,nc,H,P,N)

    # --- inter-chunk contribution --------------------------------------------
    dec_in = jnp.exp(cs)                             # decay from chunk start
    y = y + jnp.einsum("bctn,bcth,bchpn->bcthp", Cc, dec_in, h_prevs)
    return y.reshape(Bb, S, H, P), h_final


def mamba2_block(p: Params, x: jnp.ndarray, cfg, *, mode: str = "causal",
                 cache: Optional[dict] = None, chunk: int = 128) -> tuple:
    """Returns (out, new_cache); cache = {"h": (B,H,P,N), "conv": (B,K-1,conv_dim)}."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    P = cfg.ssm_head_dim
    H = di // P
    N = cfg.ssm_state
    K = cfg.ssm_conv
    conv_dim = di + 2 * N

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    # depthwise causal conv over (x, B, C)
    if mode == "decode":
        assert cache is not None and S == 1
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B,K,conv)
        new_conv = hist[:, 1:]
        xbc_c = jnp.einsum("bkc,kc->bc", hist, p["conv_w"])[:, None] + p["conv_b"]
    else:
        # depthwise causal conv via grouped conv_general_dilated
        kern = p["conv_w"].T[:, None, :]                        # (conv_dim,1,K)
        xbc_t = xbc.transpose(0, 2, 1)                          # (B,conv,S)
        conv = jax.lax.conv_general_dilated(
            xbc_t, kern, window_strides=(1,), padding=[(K - 1, 0)],
            feature_group_count=conv_dim)
        xbc_c = conv.transpose(0, 2, 1) + p["conv_b"]
        hist_tail = xbc[:, -(K - 1):] if K > 1 else xbc[:, :0]
        if K > 1 and S < K - 1:
            hist_tail = jnp.pad(hist_tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
        new_conv = hist_tail
    xbc_c = jax.nn.silu(xbc_c)
    xs, B_s, C_s = jnp.split(xbc_c, [di, di + N], axis=-1)
    xh = xs.reshape(B, -1, H, P)

    A = -jnp.exp(p["A_log"])                                     # (H,) negative
    if mode == "decode":
        dt1 = dt_h[:, 0]                                         # (B,H)
        a = jnp.exp(dt1 * A)                                     # (B,H)
        h = cache["h"] * a[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt1, B_s[:, 0], xh[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", C_s[:, 0], h)[:, None]    # (B,1,H,P)
        new_cache = {"h": h, "conv": new_conv}
    else:
        h0 = cache["h"] if cache is not None else None
        y, h = _ssd_chunked(xh.astype(jnp.float32), dt_h,
                            A, B_s.astype(jnp.float32), C_s.astype(jnp.float32),
                            chunk, h0)
        new_cache = {"h": h, "conv": new_conv} if mode == "causal" else None

    y = y + p["D"][None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(B, -1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = apply_norm(p["out_norm"], y, "rmsnorm")
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache
