from . import layers, model
from .model import (
    cache_specs,
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    param_specs,
)

__all__ = [
    "layers",
    "model",
    "cache_specs",
    "forward_decode",
    "forward_prefill",
    "forward_train",
    "init_params",
    "param_specs",
]
