"""Synthetic token pipeline: deterministic, host-sharded, prefetching.

Determinism contract: batch for (step, host) is a pure function of
(seed, step, host) — restart/elastic-rescale resumes mid-stream exactly
(``skip_to``), and no host ever blocks on another host's input queue
(straggler mitigation: the input path has no global barrier).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(
        self,
        vocab_size: int,
        batch: int,             # per-host batch
        seq_len: int,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
        prefetch: int = 2,
        extras_fn=None,         # optional fn(rng, batch) -> dict of stub inputs
    ):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = 0
        self.extras_fn = extras_fn
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        # Markov-ish stream so the loss is learnable (not pure noise)
        base = rng.integers(0, self.vocab_size, size=(self.batch, 1))
        drift = rng.integers(-3, 4, size=(self.batch, self.seq_len))
        toks = (base + np.cumsum(drift, axis=1)) % self.vocab_size
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.extras_fn:
            out.update(self.extras_fn(rng, self.batch))
        return out

    def skip_to(self, step: int) -> None:
        self.step = step

    def _work(self):
        while not self._stop.is_set():
            b = self.batch_at(self.step)
            self.step += 1
            self._q.put(b)

    def start(self) -> "TokenPipeline":
        self._worker = threading.Thread(target=self._work, daemon=True)
        self._worker.start()
        return self

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._worker is None:
            b = self.batch_at(self.step)
            self.step += 1
            return b
        return self._q.get()

    def stop(self):
        self._stop.set()
