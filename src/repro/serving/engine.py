"""Batched serving engine: prefill + greedy decode with KV caches, plus a
request scheduler that reuses the paper's levelizer for dependency-ordered
batching (requests whose prompt extends another request's output must wait
— the same "column depends on column" structure GLU levelizes).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core.dependency import levelize
from ..models.model import forward_decode, forward_prefill

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray             # (S,) prompt
    max_new: int = 16
    parent: Optional[int] = None   # must complete before this request runs
    output: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg, params, extras=None):
        self.cfg = cfg
        self.params = params
        self.extras = extras

        @partial(jax.jit, static_argnames=("max_len",))
        def _prefill(params, tokens, max_len):
            return forward_prefill(params, tokens, cfg, extras, max_len=max_len)

        @jax.jit
        def _decode(params, token, cache):
            return forward_decode(params, token, cache, cfg, extras)

        self._prefill = _prefill
        self._decode = _decode

    def generate_batch(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """prompts (B, S) -> greedy continuations (B, max_new)."""
        B, S = prompts.shape
        logits, cache = self._prefill(self.params, jnp.asarray(prompts), S + max_new)
        outs = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            outs.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return np.stack(outs, axis=1)

    # -- dependency-aware scheduling (levelizer reuse) -----------------------
    def run(self, requests: list[Request], batch_size: int = 8) -> dict[int, np.ndarray]:
        idx = {r.rid: i for i, r in enumerate(requests)}
        src, dst = [], []
        for r in requests:
            if r.parent is not None:
                src.append(idx[r.parent])
                dst.append(idx[r.rid])
        lv = levelize(len(requests),
                      np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64))
        results: dict[int, np.ndarray] = {}
        # effective (spliced) prompt per request, built WITHOUT mutating the
        # caller's Request.tokens: a grandchild still sees its parent's full
        # context through this dict, and running the scheduler twice on the
        # same request list cannot double-prepend the parent prompt
        eff: dict[int, np.ndarray] = {}
        for level in range(lv.num_levels):
            ready = [requests[i] for i in lv.columns_at(level)]
            # bucket by (prompt length, max_new) for static shapes
            buckets: dict[tuple, list[Request]] = {}
            for r in ready:
                # child prompts extend the parent's output
                toks = r.tokens
                if r.parent is not None:
                    toks = np.concatenate([eff[r.parent],
                                           results[r.parent], r.tokens])
                eff[r.rid] = toks
                buckets.setdefault((len(toks), r.max_new), []).append(r)
            for (slen, max_new), rs in buckets.items():
                for c in range(0, len(rs), batch_size):
                    group = rs[c : c + batch_size]
                    batch = np.stack([eff[r.rid] for r in group])
                    out = self.generate_batch(batch, max_new)
                    for r, o in zip(group, out):
                        r.output = o
                        results[r.rid] = o
        return results
