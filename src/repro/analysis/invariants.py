"""Plan-level invariant verification.

``verify_plan`` proves a :class:`~repro.core.plan.FactorizePlan` (or the
``SymbolicPlan`` wrapping one) correct *from first principles against the
filled pattern*: every check below recomputes its ground truth directly
from ``(indptr, indices)`` — never from the arrays being checked — so a bug
shared by the planner and the executor cannot hide behind a bit-identity
test between the two.

The race detector is the heart: :func:`repro.core.dependency
.dependencies_exact` rebuilds the column hazard DAG of the
level-synchronous executor (the j -> min(r, k) consumption rule — a strict
subset of the paper's relaxed Alg. 4 superset, a strict superset of the
GLU1.0 U-pattern rule) and every edge must be strictly level-forward.  Any
levelization that passes is a valid schedule; one that fails races on the
real executor semantics, bucket fusion or not.
"""
from __future__ import annotations

import numpy as np

from ..core.dependency import dependencies_exact
from .report import VerifyReport

__all__ = ["verify_plan"]


def _as_fplan(plan):
    """(fplan, (a_indptr, a_indices) | None) from a Symbolic- or
    FactorizePlan."""
    if hasattr(plan, "fplan"):  # SymbolicPlan
        return plan.fplan, (plan.perm_indptr, plan.perm_indices)
    return plan, None


def _norm_pattern(pattern):
    if pattern is None:
        return None
    if hasattr(pattern, "indptr"):
        return (np.asarray(pattern.indptr, dtype=np.int64),
                np.asarray(pattern.indices, dtype=np.int64))
    indptr, indices = pattern
    return (np.asarray(indptr, dtype=np.int64),
            np.asarray(indices, dtype=np.int64))


class _Ctx:
    """Shared pattern-derived ground truth for the individual checks."""

    def __init__(self, fplan):
        self.fplan = fplan
        self.n = fplan.n
        self.nnz = len(fplan.indices)
        self.indptr = np.asarray(fplan.indptr, dtype=np.int64)
        self.indices = np.asarray(fplan.indices, dtype=np.int64)
        self.cols_of = np.repeat(np.arange(self.n, dtype=np.int64),
                                 np.diff(self.indptr))
        self.lower = self.indices > self.cols_of
        self.upper = self.indices < self.cols_of
        self.nnz_l = np.bincount(self.cols_of[self.lower],
                                 minlength=self.n).astype(np.int64)
        self.levels = np.asarray(fplan.levels.levels, dtype=np.int64)


def _check_pattern(ctx: _Ctx, rep: VerifyReport) -> bool:
    rep.ran("pattern")
    f = ctx.fplan
    ok = True
    if (len(ctx.indptr) != ctx.n + 1 or ctx.indptr[0] != 0
            or np.any(np.diff(ctx.indptr) < 0)
            or ctx.indptr[-1] != len(ctx.indices)):
        rep.add("PATTERN_MALFORMED", "indptr is not a valid CSC offset array")
        return False
    if f.nnz != len(ctx.indices):
        rep.add("PATTERN_MALFORMED",
                f"plan.nnz={f.nnz} != len(indices)={len(ctx.indices)}")
        ok = False
    if len(ctx.indices) and (ctx.indices.min() < 0
                             or ctx.indices.max() >= ctx.n):
        rep.add("PATTERN_MALFORMED", "row index outside [0, n)")
        return False
    # strictly increasing rows within each column (CSC canonical form —
    # searchsorted-based plan construction and diag lookup assume it)
    same_col = ctx.cols_of[1:] == ctx.cols_of[:-1]
    bad = same_col & (np.diff(ctx.indices) <= 0)
    if np.any(bad):
        i = int(np.flatnonzero(bad)[0])
        rep.add("PATTERN_MALFORMED",
                "rows not strictly increasing within a column",
                col=int(ctx.cols_of[i]), entry=i)
        ok = False
    return ok


def _check_diag(ctx: _Ctx, rep: VerifyReport) -> bool:
    rep.ran("diag")
    di = np.asarray(ctx.fplan.diag_idx, dtype=np.int64)
    if len(di) != ctx.n or np.any(di < 0) or np.any(di >= ctx.nnz):
        rep.add("DIAG_MISMATCH", "diag_idx has wrong length or range")
        return False
    cols = np.arange(ctx.n, dtype=np.int64)
    bad = (ctx.indices[di] != cols) | (ctx.cols_of[di] != cols)
    if np.any(bad):
        j = int(np.flatnonzero(bad)[0])
        rep.add("DIAG_MISMATCH",
                f"diag_idx[{j}] points at "
                f"({int(ctx.indices[di[j]])}, {int(ctx.cols_of[di[j]])})",
                col=j, n_bad=int(bad.sum()))
        return False
    return True


def _check_levels(ctx: _Ctx, rep: VerifyReport) -> bool:
    rep.ran("levels")
    lv = ctx.fplan.levels
    levels = ctx.levels
    order = np.asarray(lv.order, dtype=np.int64)
    ptr = np.asarray(lv.level_ptr, dtype=np.int64)
    if len(levels) != ctx.n or len(order) != ctx.n:
        rep.add("LEVELS_MALFORMED", "levels/order have wrong length")
        return False
    if np.any(np.sort(order) != np.arange(ctx.n)):
        rep.add("LEVELS_MALFORMED", "order is not a permutation of [0, n)")
        return False
    nlev = len(ptr) - 1
    if ctx.n and (levels.min() < 0 or levels.max() != nlev - 1):
        rep.add("LEVELS_MALFORMED",
                f"levels span [{int(levels.min())}, {int(levels.max())}] "
                f"but level_ptr declares {nlev} levels")
        return False
    po = levels[order]
    if np.any(np.diff(po) < 0):
        rep.add("LEVELS_MALFORMED", "order is not grouped by level")
        return False
    expect_ptr = np.searchsorted(po, np.arange(nlev + 1))
    if not np.array_equal(ptr, expect_ptr):
        rep.add("LEVELS_MALFORMED", "level_ptr offsets disagree with levels")
        return False
    return True


def _check_races(ctx: _Ctx, rep: VerifyReport) -> None:
    """Recompute the exact hazard DAG from the pattern; every edge must be
    strictly level-forward.  This validates the *levelization itself* —
    the relaxed detector, the longest-path sweep, and any later level
    rewrite — against the executor's consumption semantics."""
    rep.ran("races")
    src, dst = dependencies_exact(ctx.fplan)
    lev = ctx.levels
    same = lev[src] == lev[dst]
    back = lev[src] > lev[dst]
    if np.any(same):
        idx = np.flatnonzero(same)
        for i in idx[:3]:
            rep.add("RACE_INTRA_LEVEL",
                    f"columns {int(src[i])} -> {int(dst[i])} share level "
                    f"{int(lev[src[i]])}",
                    src=int(src[i]), dst=int(dst[i]),
                    n_bad=int(same.sum()))
    if np.any(back):
        idx = np.flatnonzero(back)
        for i in idx[:3]:
            rep.add("RACE_LEVEL_ORDER",
                    f"edge {int(src[i])} (level {int(lev[src[i]])}) -> "
                    f"{int(dst[i])} (level {int(lev[dst[i]])}) points "
                    "level-backward",
                    src=int(src[i]), dst=int(dst[i]),
                    n_bad=int(back.sum()))


def _check_segments(ctx: _Ctx, rep: VerifyReport) -> bool:
    """Segments partition the norm/update arrays contiguously in level
    order and list exactly the levelization's columns."""
    segs = ctx.fplan.segments
    lv = ctx.fplan.levels
    npos = upos = 0
    ok = True
    for i, seg in enumerate(segs):
        if seg.level != i:
            rep.add("LEVELS_MALFORMED",
                    f"segment {i} carries level {seg.level}")
            ok = False
        if seg.norm_slice.start != npos or seg.upd_slice.start != upos:
            rep.add("LEVELS_MALFORMED",
                    f"segment {i} slices are not contiguous")
            ok = False
        npos, upos = seg.norm_slice.stop, seg.upd_slice.stop
        if i < lv.num_levels and not np.array_equal(
                np.sort(np.asarray(seg.cols)), np.sort(lv.columns_at(i))):
            rep.add("LEVELS_MALFORMED",
                    f"segment {i} columns differ from the levelization's")
            ok = False
    if len(segs) != lv.num_levels:
        rep.add("LEVELS_MALFORMED",
                f"{len(segs)} segments for {lv.num_levels} levels")
        ok = False
    if npos != len(ctx.fplan.norm_idx) or upos != len(ctx.fplan.lidx):
        rep.add("LEVELS_MALFORMED",
                "segment slices do not cover the plan arrays")
        ok = False
    return ok


def _check_norm(ctx: _Ctx, rep: VerifyReport) -> None:
    rep.ran("norm")
    f = ctx.fplan
    ni = np.asarray(f.norm_idx, dtype=np.int64)
    nd = np.asarray(f.norm_diag, dtype=np.int64)
    if len(ni) != len(nd):
        rep.add("NORM_MISMATCH", "norm_idx/norm_diag length mismatch")
        return
    for name, a in (("norm_idx", ni), ("norm_diag", nd)):
        if len(a) and (a.min() < 0 or a.max() >= ctx.nnz):
            rep.add("NORM_OOB", f"{name} outside [0, nnz)",
                    n_bad=int(((a < 0) | (a >= ctx.nnz)).sum()))
            return
    di = np.asarray(f.diag_idx, dtype=np.int64)
    bad = ctx.indices[ni] <= ctx.cols_of[ni]
    if np.any(bad):
        rep.add("NORM_MISMATCH",
                "norm entry not strictly below the diagonal",
                n_bad=int(bad.sum()))
    bad = nd != di[ctx.cols_of[ni]]
    if np.any(bad):
        rep.add("NORM_MISMATCH",
                "norm_diag is not the entry's own column diagonal",
                n_bad=int(bad.sum()))
    low_idx = np.flatnonzero(ctx.lower)
    if not np.array_equal(np.sort(ni), low_idx):
        rep.add("NORM_MISMATCH",
                "normalised entries are not exactly the pattern's L entries",
                got=len(ni), want=len(low_idx))
    # per-level: each segment normalises its own columns' L entries
    for seg in ctx.fplan.segments:
        got = np.sort(ctx.cols_of[ni[seg.norm_slice]])
        want = np.sort(np.repeat(np.asarray(seg.cols, dtype=np.int64),
                                 ctx.nnz_l[seg.cols]))
        if not np.array_equal(got, want):
            rep.add("NORM_MISMATCH",
                    f"level {seg.level} normalises the wrong columns",
                    level=seg.level)
            break


def _check_triples(ctx: _Ctx, rep: VerifyReport) -> None:
    rep.ran("triples")
    f = ctx.fplan
    li = np.asarray(f.lidx, dtype=np.int64)
    ui = np.asarray(f.uidx, dtype=np.int64)
    di = np.asarray(f.didx, dtype=np.int64)
    dc = np.asarray(f.dst_col, dtype=np.int64)
    if not (len(li) == len(ui) == len(di) == len(dc)):
        rep.add("TRIPLE_INCONSISTENT", "triple arrays have unequal lengths")
        return
    for name, a, hi in (("lidx", li, ctx.nnz), ("uidx", ui, ctx.nnz),
                        ("didx", di, ctx.nnz), ("dst_col", dc, ctx.n)):
        if len(a) and (a.min() < 0 or a.max() >= hi):
            rep.add("TRIPLE_OOB", f"{name} outside [0, {hi})",
                    n_bad=int(((a < 0) | (a >= hi)).sum()))
            return
    rows, cols = ctx.indices, ctx.cols_of
    # one relational pass pins every triple to the factorization update
    # vals[(r, k)] -= vals[(r, j)] * vals[(j, k)] with r > j, k > j
    bad = cols[li] >= rows[li]
    if np.any(bad):
        rep.add("TRIPLE_INCONSISTENT", "lidx is not a strict L entry",
                n_bad=int(bad.sum()))
    bad = rows[ui] != cols[li]
    if np.any(bad):
        rep.add("TRIPLE_INCONSISTENT",
                "uidx row is not the triple's source column",
                n_bad=int(bad.sum()))
    bad = cols[ui] <= rows[ui]
    if np.any(bad):
        rep.add("TRIPLE_INCONSISTENT", "uidx is not a strict U entry",
                n_bad=int(bad.sum()))
    bad = dc != cols[ui]
    if np.any(bad):
        rep.add("TRIPLE_INCONSISTENT",
                "dst_col differs from uidx's column",
                n_bad=int(bad.sum()))
    bad = (rows[di] != rows[li]) | (cols[di] != dc)
    if np.any(bad):
        i = int(np.flatnonzero(bad)[0])
        rep.add("TRIPLE_INCONSISTENT",
                "didx does not address (row(lidx), dst_col)",
                triple=i, n_bad=int(bad.sum()))
    # completeness: the consistency pass shows every triple IS a valid
    # update; exact count + (lidx, uidx) uniqueness then pigeonhole the
    # multiset to exactly { (L entry of j) x (U-row entry of j) : all j }
    from ..sparse.csc import csc_transpose_pattern

    indptr_t, indices_t, _ = csc_transpose_pattern(
        ctx.n, ctx.fplan.indptr, ctx.fplan.indices)
    rws = np.repeat(np.arange(ctx.n, dtype=np.int64), np.diff(indptr_t))
    n_up_row = np.bincount(rws[np.asarray(indices_t, dtype=np.int64) > rws],
                           minlength=ctx.n).astype(np.int64)
    want = int((ctx.nnz_l * n_up_row).sum())
    if len(li) != want:
        rep.add("TRIPLE_SET_MISMATCH",
                f"{len(li)} update triples, pattern requires {want}")
    key = li * ctx.nnz + ui
    if len(np.unique(key)) != len(key):
        rep.add("TRIPLE_SET_MISMATCH", "duplicate (lidx, uidx) pair")
    # order: sorted by (source level, destination column) — the segmented
    # executor layouts assume contiguous per-destination runs per level
    lev = ctx.levels[cols[li]]
    okey = lev * ctx.n + dc
    if np.any(np.diff(okey) < 0):
        rep.add("TRIPLE_ORDER",
                "triples not sorted by (level, destination column)")
    for seg in ctx.fplan.segments:
        if not np.all(lev[seg.upd_slice] == seg.level):
            rep.add("TRIPLE_ORDER",
                    f"level-{seg.level} segment contains foreign triples",
                    level=seg.level)
            break


def _check_scatter(ctx: _Ctx, rep: VerifyReport, a_pattern) -> None:
    rep.ran("scatter")
    asc = np.asarray(ctx.fplan.a_scatter, dtype=np.int64)
    if len(asc) and (asc.min() < 0 or asc.max() >= ctx.nnz):
        rep.add("SCATTER_OOB", "a_scatter outside [0, nnz)",
                n_bad=int(((asc < 0) | (asc >= ctx.nnz)).sum()))
        return
    uniq, counts = np.unique(asc, return_counts=True)
    if np.any(counts > 1):
        s = int(uniq[np.argmax(counts)])
        rep.add("SCATTER_COLLISION",
                f"{int((counts > 1).sum())} filled slot(s) receive multiple "
                "A entries", slot=s)
    if a_pattern is None:
        return
    a_indptr, a_indices = a_pattern
    a_cols = np.repeat(np.arange(len(a_indptr) - 1, dtype=np.int64),
                       np.diff(a_indptr))
    if len(asc) != len(a_indices):
        rep.add("SCATTER_MISMATCH",
                f"{len(asc)} scatter slots for {len(a_indices)} A entries")
        return
    bad = (ctx.indices[asc] != a_indices) | (ctx.cols_of[asc] != a_cols)
    if np.any(bad):
        i = int(np.flatnonzero(bad)[0])
        rep.add("SCATTER_MISMATCH",
                "a_scatter target coordinates differ from A's",
                entry=i, n_bad=int(bad.sum()))


def _check_trisolve_fwd(ctx: _Ctx, rep: VerifyReport) -> None:
    rep.ran("trisolve_fwd")
    f = ctx.fplan
    fr = np.asarray(f.fwd_rows, dtype=np.int64)
    fc = np.asarray(f.fwd_cols, dtype=np.int64)
    fv = np.asarray(f.fwd_vidx, dtype=np.int64)
    ptr = np.asarray(f.fwd_ptr, dtype=np.int64)
    if len(fv) and (fv.min() < 0 or fv.max() >= ctx.nnz):
        rep.add("TRISOLVE_FWD_SET", "fwd_vidx outside [0, nnz)")
        return
    bad = (ctx.indices[fv] != fr) | (ctx.cols_of[fv] != fc) | (fr <= fc)
    if np.any(bad):
        rep.add("TRISOLVE_FWD_SET",
                "fwd rows/cols disagree with the L entries they index",
                n_bad=int(bad.sum()))
    if not np.array_equal(np.sort(fv), np.flatnonzero(ctx.lower)):
        rep.add("TRISOLVE_FWD_SET",
                "forward schedule is not exactly the pattern's L entries",
                got=len(fv), want=int(ctx.lower.sum()))
    if (ptr[0] != 0 or ptr[-1] != len(fv) or np.any(np.diff(ptr) < 0)):
        rep.add("TRISOLVE_FWD_SET", "fwd_ptr is not a valid offset array")
        return
    # step-timing happens-before: entry (r, c) at step t reads x[c] (the
    # gather sees pre-step state) and writes x[r]; every write into a
    # column must land strictly before that column's first read
    step = np.searchsorted(ptr, np.arange(len(fv)), side="right") - 1
    wmax = np.full(ctx.n, -1, dtype=np.int64)
    np.maximum.at(wmax, fr, step)
    rmin = np.full(ctx.n, len(ptr), dtype=np.int64)
    np.minimum.at(rmin, fc, step)
    bad = wmax >= rmin
    if np.any(bad):
        c = int(np.flatnonzero(bad)[0])
        rep.add("TRISOLVE_FWD_RACE",
                f"x[{c}] is written at step {int(wmax[c])} but read at "
                f"step {int(rmin[c])}",
                col=c, n_bad=int(bad.sum()))


def _check_trisolve_bwd(ctx: _Ctx, rep: VerifyReport) -> None:
    rep.ran("trisolve_bwd")
    f = ctx.fplan
    br = np.asarray(f.bwd_rows, dtype=np.int64)
    bc = np.asarray(f.bwd_cols, dtype=np.int64)
    bv = np.asarray(f.bwd_vidx, dtype=np.int64)
    ptr = np.asarray(f.bwd_ptr, dtype=np.int64)
    blc = np.asarray(f.bwd_level_cols, dtype=np.int64)
    cptr = np.asarray(f.bwd_col_ptr, dtype=np.int64)
    if not np.array_equal(np.sort(blc), np.arange(ctx.n)):
        rep.add("TRISOLVE_BWD_SET",
                "bwd_level_cols is not a permutation of [0, n) — some "
                "column is divided twice or never")
        return
    if (cptr[0] != 0 or cptr[-1] != ctx.n or np.any(np.diff(cptr) < 0)
            or len(cptr) != len(ptr)):
        rep.add("TRISOLVE_BWD_SET", "bwd_col_ptr is not a valid offset array")
        return
    if len(bv) and (bv.min() < 0 or bv.max() >= ctx.nnz):
        rep.add("TRISOLVE_BWD_SET", "bwd_vidx outside [0, nnz)")
        return
    bad = (ctx.indices[bv] != br) | (ctx.cols_of[bv] != bc) | (br >= bc)
    if np.any(bad):
        rep.add("TRISOLVE_BWD_SET",
                "bwd rows/cols disagree with the U entries they index",
                n_bad=int(bad.sum()))
    if not np.array_equal(np.sort(bv), np.flatnonzero(ctx.upper)):
        rep.add("TRISOLVE_BWD_SET",
                "backward schedule is not exactly the pattern's strict "
                "U entries", got=len(bv), want=int(ctx.upper.sum()))
    if (ptr[0] != 0 or ptr[-1] != len(bv) or np.any(np.diff(ptr) < 0)):
        rep.add("TRISOLVE_BWD_SET", "bwd_ptr is not a valid offset array")
        return
    # step timing: step t first divides x[c] for its level columns, THEN
    # applies its updates (sequential inside the traced step body).  An
    # update (r, c) at step t therefore needs x[c] divided at a step <= t
    # and must land strictly before x[r]'s division.
    t_div = np.empty(ctx.n, dtype=np.int64)
    t_div[blc] = np.searchsorted(cptr, np.arange(ctx.n), side="right") - 1
    t_e = np.searchsorted(ptr, np.arange(len(bv)), side="right") - 1
    bad = (t_div[bc] > t_e) | (t_e >= t_div[br])
    if np.any(bad):
        i = int(np.flatnonzero(bad)[0])
        rep.add("TRISOLVE_BWD_RACE",
                f"update ({int(br[i])}, {int(bc[i])}) at step {int(t_e[i])} "
                f"races divisions at steps {int(t_div[br[i]])} (row) / "
                f"{int(t_div[bc[i]])} (col)",
                entry=i, n_bad=int(bad.sum()))


def _reach_reference(ctx: _Ctx, seeds, direction: str) -> np.ndarray:
    """Independent Python-set BFS on the pattern itself (no plan arrays)."""
    visited = set(int(s) for s in np.asarray(seeds).ravel())
    stack = list(visited)
    while stack:
        j = stack.pop()
        s, e = int(ctx.indptr[j]), int(ctx.indptr[j + 1])
        rows = ctx.indices[s:e]
        nbrs = rows[rows > j] if direction == "fwd" else rows[rows < j]
        for r in nbrs.tolist():
            if r not in visited:
                visited.add(r)
                stack.append(r)
    return np.asarray(sorted(visited), dtype=np.int64)


def _check_reach(ctx: _Ctx, rep: VerifyReport, trials: int, seed: int,
                 seed_sets) -> None:
    rep.ran("reach")
    f = ctx.fplan
    # structural: the plan's DAG adjacency must be the pattern's, column
    # major — a truncated/shifted adjacency under-approximates closures
    want_ptr = np.concatenate([[0], np.cumsum(ctx.nnz_l)])
    if not (np.array_equal(np.asarray(f.l_adj_ptr, dtype=np.int64), want_ptr)
            and np.array_equal(np.asarray(f.l_adj_rows, dtype=np.int64),
                               ctx.indices[ctx.lower])):
        rep.add("REACH_ADJ_MISMATCH",
                "L adjacency differs from the pattern's below-diagonal rows")
    nnz_u = np.bincount(ctx.cols_of[ctx.upper],
                        minlength=ctx.n).astype(np.int64)
    want_ptr = np.concatenate([[0], np.cumsum(nnz_u)])
    if not (np.array_equal(np.asarray(f.u_adj_ptr, dtype=np.int64), want_ptr)
            and np.array_equal(np.asarray(f.u_adj_rows, dtype=np.int64),
                               ctx.indices[ctx.upper])):
        rep.add("REACH_ADJ_MISMATCH",
                "U adjacency differs from the pattern's above-diagonal rows")
    if seed_sets is None:
        rng = np.random.default_rng(seed)
        seed_sets = [rng.integers(0, ctx.n, size=int(rng.integers(1, 4)))
                     for _ in range(trials)] if ctx.n else []
    for seeds in seed_sets:
        seeds = np.asarray(seeds, dtype=np.int64)
        for direction, fn in (("fwd", f.fwd_reach), ("bwd", f.bwd_reach)):
            got = np.asarray(fn(seeds), dtype=np.int64)
            ref = _reach_reference(ctx, seeds, direction)
            missing = np.setdiff1d(ref, got)
            extra = np.setdiff1d(got, ref)
            if missing.size:
                rep.add("REACH_UNDER",
                        f"{direction} reach of {seeds.tolist()} misses "
                        f"{missing.size} column(s)",
                        first=int(missing[0]))
            if extra.size:
                rep.add("REACH_OVER",
                        f"{direction} reach of {seeds.tolist()} includes "
                        f"{extra.size} unreachable column(s)",
                        first=int(extra[0]))


def verify_plan(plan, pattern=None, *, reach_trials: int = 8, seed: int = 0,
                reach_seed_sets=None) -> VerifyReport:
    """Verify a plan against the matrix pattern it claims to schedule.

    Parameters
    ----------
    plan: :class:`~repro.core.planner.SymbolicPlan` or
        :class:`~repro.core.plan.FactorizePlan`.
    pattern: optional original (pre-fill) A pattern — anything with
        ``.indptr``/``.indices`` or an ``(indptr, indices)`` tuple — used to
        pin the ``a_scatter`` coordinates.  A ``SymbolicPlan`` supplies its
        own permuted pattern; without one the scatter check still proves
        bounds and injectivity.
    reach_trials / seed / reach_seed_sets: random seed sets for the
        closure-soundness trials (explicit ``reach_seed_sets`` overrides
        the random draw — mutation tests aim them at known columns).

    Returns a :class:`VerifyReport`; it never raises — callers choose via
    ``report.raise_if_violated()``.
    """
    fplan, a_pattern = _as_fplan(plan)
    if pattern is not None:
        a_pattern = _norm_pattern(pattern)
    rep = VerifyReport()
    ctx = _Ctx(fplan)
    if not _check_pattern(ctx, rep):
        return rep          # nothing else can be trusted to even index
    diag_ok = _check_diag(ctx, rep)
    levels_ok = _check_levels(ctx, rep)
    if levels_ok:
        _check_races(ctx, rep)
        _check_segments(ctx, rep)
    if diag_ok:
        _check_norm(ctx, rep)
        _check_triples(ctx, rep)
    _check_scatter(ctx, rep, a_pattern)
    _check_trisolve_fwd(ctx, rep)
    _check_trisolve_bwd(ctx, rep)
    _check_reach(ctx, rep, reach_trials, seed, reach_seed_sets)
    return rep
