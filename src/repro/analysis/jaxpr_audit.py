"""Static audit of the fused whole-schedule programs.

Walks the jaxpr of the executor's single-dispatch runners and asserts the
properties the performance story rests on:

* **zero host callbacks** — no ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` (or infeed/outfeed) primitive anywhere in the traced
  program, so a factorization never synchronises with the host mid-flight;
* **donation contract** — the factorize ``entry="filled"`` runner donates
  its value buffer (argument 0), the trisolve runner donates NOTHING
  (the caller retains the factors and the rhs; donation there was the PR 5
  use-after-free bug).  The audit reads the ``tf.aliasing_output`` /
  ``jax.buffer_donor`` markers off the lowered StableHLO, i.e. what XLA
  will actually do, not what the Python wrapper asked for;
* **one dispatch** — the whole schedule is a single jitted callable
  (``jit_schedule=True``), so a (re)factorization or solve is one device
  program launch.

What this does NOT guarantee: numeric correctness (that is
``verify_plan``/``verify_executor``'s job), compile-cache behaviour across
distinct plans, or device-side performance of the lowered program.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .report import VerifyReport

__all__ = ["audit_factorize", "audit_trisolve", "CALLBACK_PRIMITIVES"]

# primitive names that imply a host round-trip inside the program
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
})

_DONOR_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")


def _iter_subjaxprs(params: dict):
    core = jax.core
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if isinstance(x, core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, core.Jaxpr):
                yield x


def collect_primitives(jaxpr) -> set:
    """Every primitive name reachable from ``jaxpr`` (sub-jaxprs included)."""
    seen = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            seen.add(eqn.primitive.name)
            stack.extend(_iter_subjaxprs(eqn.params))
    return seen


def _audit_traced(runner, args, *, name: str, expect_donated: int,
                  rep: VerifyReport) -> None:
    rep.ran(f"audit_{name}")
    closed = jax.make_jaxpr(runner)(*args)
    prims = collect_primitives(closed.jaxpr)
    hits = sorted(prims & CALLBACK_PRIMITIVES)
    if hits:
        rep.add("AUDIT_CALLBACK",
                f"{name} runner contains host callback primitive(s) "
                f"{hits}", runner=name)
    text = runner.lower(*args).as_text()
    donors = sum(text.count(m) for m in _DONOR_MARKERS)
    if donors != expect_donated:
        rep.add("AUDIT_DONATION",
                f"{name} runner marks {donors} donated buffer(s), "
                f"contract requires {expect_donated}",
                runner=name, donors=donors)


def audit_factorize(fact, entry: str = "filled") -> VerifyReport:
    """Audit a :class:`~repro.core.factorize.JaxFactorizer`'s fused runner.

    ``entry="filled"`` must donate exactly its value buffer; the
    ``"scatter"`` entry takes the caller's (retained) A values and donates
    nothing.
    """
    rep = VerifyReport()
    if not fact.jit_schedule:
        rep.ran("audit_factorize")
        rep.add("AUDIT_DISPATCH",
                "jit_schedule=False: factorization issues one dispatch per "
                f"group ({fact.n_groups} groups), not one total")
        return rep
    runner = fact._runner_for(entry, batched=False, shard=None)
    if entry == "filled":
        a = jnp.zeros(fact.layout.storage_shape(fact.nnz),
                      dtype=fact.storage_dtype)
        expect = 1
    else:
        a = jnp.zeros((len(np.asarray(fact._a_scatter)),), dtype=fact.dtype)
        expect = 0
    robust = fact.static_pivot is not None
    eps = (jnp.asarray(fact.static_pivot, dtype=fact.storage_dtype)
           if robust else None)
    _audit_traced(
        runner,
        (a, fact._a_scatter, fact._group_arrays, fact._group_diags, eps),
        name="factorize", expect_donated=expect, rep=rep)
    return rep


def audit_trisolve(solver, dtype=None) -> VerifyReport:
    """Audit a :class:`~repro.core.triangular.JaxTriangularSolver`'s fused
    full-schedule runner.  The trisolve contract is ZERO donated buffers:
    the caller retains both the factor values and the right-hand side."""
    from ..core.triangular import _build_trisolve_runner

    rep = VerifyReport()
    if not solver.jit_schedule:
        rep.ran("audit_trisolve")
        fwd, bwd = solver._full_schedule
        rep.add("AUDIT_DISPATCH",
                "jit_schedule=False: a solve issues one dispatch per level "
                f"group ({len(fwd) + len(bwd)} groups), not one total")
        return rep
    planar = solver._planar
    runner = solver._exec_cache.get_or_build(
        ("trisolve", solver.plan.digest, "full", "single",
         None, solver.layout),
        lambda: _build_trisolve_runner("single", planar=planar, shard=None))
    nnz, n = solver.plan.nnz, solver.plan.n
    if planar:
        vals = jnp.zeros((nnz, 2), dtype=dtype or jnp.float64)
        b = jnp.zeros(n, dtype=jnp.complex128 if vals.dtype == jnp.float64
                      else jnp.complex64)
    else:
        vals = jnp.zeros(nnz, dtype=dtype or jnp.float64)
        b = jnp.zeros(n, dtype=vals.dtype)
    fwd, bwd = solver._full_schedule
    _audit_traced(runner, (vals, b, tuple(fwd), tuple(bwd)),
                  name="trisolve", expect_donated=0, rep=rep)
    return rep
