"""Executed-schedule verification: the post-bucketing groups.

``verify_plan`` proves the *plan* is a valid schedule; this module proves
the *executor actually built that schedule*.  Bucket fusion, scan stacking,
Pallas (D, R, C) layouts and the dense trailing block all rewrite the plan
arrays into padded device buffers — a bug there (e.g. a bucket merge fusing
a producer level with its consumer) would race while every plan-level check
still passes.

The walk reconstructs the step sequence the device runs: each scan row /
flat level / Pallas level is one step; a step first normalises (time
``2t``: gathers read pre-step state, then the set lands), then applies its
update triples (time ``2t + 1``: l/u gathers read, the scatter-add
writes).  Happens-before is then a pure index computation over the value
array: for every entry, the max update-write time must be strictly below
the min consuming-read time.  This is exact for the executor semantics —
gathers in a step see pre-step state, so a same-time write/read pair IS a
race — and it is schedule-agnostic: merged, reordered, or mis-bucketed
steps are caught without knowing how the schedule was derived.
"""
from __future__ import annotations

import numpy as np

from .report import VerifyReport

__all__ = ["verify_executor", "verify_trisolver"]

_BIG = 1 << 40


def _steps_from_groups(kinds, group_arrays, nnz, rep):
    """Flatten executor groups into per-step (ni, nd, li, ui, di) int64
    tuples; returns (steps, dense_arrays_or_None)."""
    steps = []
    dense = None
    for gi, (kind, arrs) in enumerate(zip(kinds, group_arrays)):
        if kind == "dense":
            if gi != len(kinds) - 1:
                rep.add("EXEC_DENSE_TAIL",
                        f"dense group at position {gi} is not last")
            dense = tuple(np.asarray(a) for a in arrs)
            continue
        if kind in ("scan", "flat"):
            a = [np.asarray(x).astype(np.int64) for x in arrs]
            for k in range(a[0].shape[0]):
                steps.append(tuple(x[k] for x in a))
        elif kind == "pallas":
            ni, nd, li2, ui2, dl, pos = [np.asarray(x).astype(np.int64)
                                         for x in arrs]
            D, R = li2.shape
            C = pos.shape[1]
            if np.any((dl < 0) | (dl > C)):
                rep.add("EXEC_PAD_OOB",
                        f"pallas didx_local outside [0, {C}]", group=gi)
                dl = np.clip(dl, 0, C)
            if np.any((pos < 0) | (pos > nnz)):
                rep.add("EXEC_PAD_OOB",
                        "pallas pos outside [0, nnz]", group=gi)
                pos = np.clip(pos, 0, nnz)
            rr = np.repeat(np.arange(D), R)
            dlf = dl.ravel()
            # local in-column offset -> global value index; the sentinel C
            # and padded pos slots both resolve to the drop index nnz
            di = np.where(dlf < C, pos[rr, np.minimum(dlf, C - 1)], nnz)
            steps.append((ni, nd, li2.ravel(), ui2.ravel(), di))
        else:
            rep.add("EXEC_PAD_OOB", f"unknown group kind {kind!r}", group=gi)
    return steps, dense


def _dense_tail_want(plan, c_star, Np):
    """The ground-truth (Np, Np) position map of the trailing block."""
    n, nnz = plan.n, plan.nnz
    indptr = np.asarray(plan.indptr, dtype=np.int64)
    indices = np.asarray(plan.indices, dtype=np.int64)
    cols_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    m = (indices >= c_star) & (cols_of >= c_star)
    want = np.full((Np, Np), nnz, dtype=np.int64)
    want[indices[m] - c_star, cols_of[m] - c_star] = np.flatnonzero(m)
    return want


def verify_executor(fact, *, kinds=None, group_arrays=None) -> VerifyReport:
    """Verify a built :class:`~repro.core.factorize.JaxFactorizer` schedule
    against its plan.  ``kinds``/``group_arrays`` override the factorizer's
    own (the mutation tests feed corrupted schedules through a golden
    factorizer)."""
    plan = fact.plan
    nnz = plan.nnz
    rep = VerifyReport()
    rep.ran("exec_schedule")
    kinds = fact._kinds if kinds is None else tuple(kinds)
    group_arrays = (fact._group_arrays if group_arrays is None
                    else tuple(group_arrays))
    steps, dense = _steps_from_groups(kinds, group_arrays, nnz, rep)

    info = fact.dense_tail_info
    level_cut = plan.num_levels if info is None else info["level_cut"]
    if (dense is None) != (info is None):
        rep.add("EXEC_DENSE_TAIL",
                "dense group and dense_tail_info disagree on existence")
        return rep

    indptr = np.asarray(plan.indptr, dtype=np.int64)
    indices = np.asarray(plan.indices, dtype=np.int64)
    cols_of = np.repeat(np.arange(plan.n, dtype=np.int64), np.diff(indptr))
    diag_idx = np.asarray(plan.diag_idx, dtype=np.int64)

    # slot nnz is the legal drop/fill pad; one extra slot absorbs it so the
    # timing scatters below never special-case padding
    wmax = np.full(nnz + 1, -_BIG, dtype=np.int64)
    rmin = np.full(nnz + 1, _BIG, dtype=np.int64)
    nwrite = np.full(nnz + 1, -1, dtype=np.int64)
    exec_norms, exec_ndiag = [], []
    exec_li, exec_ui, exec_di, exec_t = [], [], [], []

    for t, (ni, nd, li, ui, di) in enumerate(steps):
        for name, a in (("norm_idx", ni), ("norm_diag", nd), ("lidx", li),
                        ("uidx", ui), ("didx", di)):
            if len(a) and (a.min() < 0 or a.max() > nnz):
                rep.add("EXEC_PAD_OOB", f"{name} outside [0, nnz]", step=t)
                return rep
        m = ni != nnz
        if np.any(nd[m] == nnz):
            rep.add("EXEC_PAD_OOB",
                    "norm entry with padded diagonal slot", step=t)
        nmv = ni[m]
        nwrite[nmv] = 2 * t
        np.minimum.at(rmin, nmv, 2 * t)      # the norm's own gather
        np.minimum.at(rmin, nd[m], 2 * t)    # the diagonal read
        exec_norms.append(nmv)
        exec_ndiag.append(nd[m])
        mu = (li != nnz) & (ui != nnz) & (di != nnz)
        mixed = (li != nnz) | (ui != nnz) | (di != nnz)
        if np.any(mixed & ~mu):
            rep.add("EXEC_PAD_OOB", "partially padded update triple", step=t)
        np.minimum.at(rmin, li[mu], 2 * t + 1)
        np.minimum.at(rmin, ui[mu], 2 * t + 1)
        np.maximum.at(wmax, di[mu], 2 * t + 1)
        exec_li.append(li[mu])
        exec_ui.append(ui[mu])
        exec_di.append(di[mu])
        exec_t.append(np.full(int(mu.sum()), t, dtype=np.int64))

    T = len(steps)
    if dense is not None:
        # the dense step gathers every trailing-block entry at its start
        c_star = info["c_star"]
        m = (indices >= c_star) & (cols_of >= c_star)
        np.minimum.at(rmin, np.flatnonzero(m), 2 * T)

    bad = wmax[:nnz] >= rmin[:nnz]
    if np.any(bad):
        e = int(np.flatnonzero(bad)[0])
        rep.add("EXEC_RACE",
                f"entry {e} ({int(indices[e])}, {int(cols_of[e])}) is "
                f"written at time {int(wmax[e])} but read at time "
                f"{int(rmin[e])}",
                entry=e, n_bad=int(bad.sum()))

    if exec_li:
        li = np.concatenate(exec_li)
        ui = np.concatenate(exec_ui)
        di = np.concatenate(exec_di)
        ts = np.concatenate(exec_t)
        bad = nwrite[li] > 2 * ts + 1
        never = nwrite[li] < 0
        if np.any(bad | never):
            i = int(np.flatnonzero(bad | never)[0])
            rep.add("EXEC_SOURCE_ORDER",
                    f"update at step {int(ts[i])} consumes entry "
                    f"{int(li[i])} normalised at time {int(nwrite[li[i]])}",
                    n_bad=int((bad | never).sum()))
    else:
        li = ui = di = np.zeros(0, dtype=np.int64)

    # coverage: the sparse steps must execute EXACTLY the plan's pre-cut
    # normalisations and triples (each once; the dense block owns the rest)
    norm_end = upd_end = 0
    if level_cut > 0 and plan.segments:
        last = plan.segments[min(level_cut, len(plan.segments)) - 1]
        norm_end = last.norm_slice.stop
        upd_end = last.upd_slice.stop
    got_n = (np.sort(np.concatenate(exec_norms)) if exec_norms
             else np.zeros(0, dtype=np.int64))
    want_n = np.sort(np.asarray(plan.norm_idx[:norm_end], dtype=np.int64))
    if not np.array_equal(got_n, want_n):
        rep.add("EXEC_NORM_COVERAGE",
                "executed normalisations differ from the plan's",
                got=len(got_n), want=len(want_n))
    nd_all = (np.concatenate(exec_ndiag) if exec_ndiag
              else np.zeros(0, dtype=np.int64))
    ni_all = (np.concatenate(exec_norms) if exec_norms
              else np.zeros(0, dtype=np.int64))
    if np.any(nd_all != diag_idx[cols_of[ni_all]]):
        rep.add("EXEC_NORM_COVERAGE",
                "executed norm diagonal is not the entry's column diagonal")
    key = li * (nnz + 1) + ui
    order = np.argsort(key, kind="stable")
    pli = np.asarray(plan.lidx[:upd_end], dtype=np.int64)
    pui = np.asarray(plan.uidx[:upd_end], dtype=np.int64)
    pdi = np.asarray(plan.didx[:upd_end], dtype=np.int64)
    pkey = pli * (nnz + 1) + pui
    porder = np.argsort(pkey, kind="stable")
    if not (len(key) == len(pkey)
            and np.array_equal(key[order], pkey[porder])
            and np.array_equal(di[order], pdi[porder])):
        rep.add("EXEC_UPDATE_COVERAGE",
                "executed update triples differ from the plan's",
                got=len(key), want=len(pkey))

    if dense is not None:
        rep.ran("dense_tail")
        c_star, Np = info["c_star"], info["padded"]
        size = info["size"]
        pos, eye = dense[0].astype(np.int64), np.asarray(dense[1])
        levels = np.asarray(plan.levels.levels, dtype=np.int64)
        tail_cols = np.flatnonzero(levels >= level_cut)
        if not np.array_equal(tail_cols, np.arange(c_star, plan.n)):
            rep.add("EXEC_DENSE_TAIL",
                    "columns at levels >= level_cut are not exactly "
                    f"[{c_star}, n)")
        if pos.shape != (Np, Np) or size != plan.n - c_star:
            rep.add("EXEC_DENSE_TAIL", "dense position map has wrong shape")
        else:
            want = _dense_tail_want(plan, c_star, Np)
            if not np.array_equal(pos, want):
                rep.add("EXEC_DENSE_TAIL",
                        "dense position map disagrees with the pattern",
                        n_bad=int((pos != want).sum()))
            want_eye = np.zeros((Np, Np), dtype=eye.dtype)
            ii = np.arange(size, Np)
            want_eye[ii, ii] = 1.0
            if not np.array_equal(eye, want_eye):
                rep.add("EXEC_DENSE_TAIL",
                        "padded-diagonal eye mask is wrong")
    return rep


def _trisolve_steps(groups, width):
    """Flatten stacked (K, P) trisolve groups into per-step tuples."""
    steps = []
    for arrs in groups:
        a = [np.asarray(x).astype(np.int64) for x in arrs]
        if len(a) != width:
            raise ValueError(f"expected {width} arrays per group")
        for k in range(a[0].shape[0]):
            steps.append(tuple(x[k] for x in a))
    return steps


def verify_trisolver(solver, *, fwd_groups=None, bwd_groups=None
                     ) -> VerifyReport:
    """Verify a built :class:`~repro.core.triangular.JaxTriangularSolver`
    full schedule against its plan (same step-timing discipline as
    :func:`verify_executor`, on the solution vector instead of the value
    array)."""
    plan = solver.plan
    n, nnz = plan.n, plan.nnz
    rep = VerifyReport()
    rep.ran("trisolve_schedule")
    if fwd_groups is None or bwd_groups is None:
        fg, bg = solver._full_schedule
        fwd_groups = fg if fwd_groups is None else fwd_groups
        bwd_groups = bg if bwd_groups is None else bwd_groups
    indptr = np.asarray(plan.indptr, dtype=np.int64)
    indices = np.asarray(plan.indices, dtype=np.int64)
    cols_of = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    diag_idx = np.asarray(plan.diag_idx, dtype=np.int64)
    lower = indices > cols_of
    upper = indices < cols_of

    # forward sweep: step t reads x[cols] (pre-step) and adds into x[rows]
    fsteps = _trisolve_steps(fwd_groups, 3)
    wmax = np.full(n + 1, -_BIG, dtype=np.int64)
    rmin = np.full(n + 1, _BIG, dtype=np.int64)
    fvs = []
    for t, (rows, cols, vidx) in enumerate(fsteps):
        if np.any((vidx < 0) | (vidx > nnz)) or np.any(
                (rows < 0) | (rows > n)) or np.any((cols < 0) | (cols > n)):
            rep.add("TRISOLVE_FWD_SET", "executed index out of range", step=t)
            return rep
        m = vidx != nnz
        if np.any((rows[m] == n) | (cols[m] == n)):
            rep.add("TRISOLVE_FWD_SET",
                    "valid entry with padded row/col slot", step=t)
        r, c, v = rows[m], cols[m], vidx[m]
        bad = (indices[v] != r) | (cols_of[v] != c) | (r <= c)
        if np.any(bad):
            rep.add("TRISOLVE_FWD_SET",
                    "executed entry disagrees with the L entry it indexes",
                    step=t, n_bad=int(bad.sum()))
        np.minimum.at(rmin, c, t)
        np.maximum.at(wmax, r, t)
        fvs.append(v)
    got = np.sort(np.concatenate(fvs)) if fvs else np.zeros(0, dtype=np.int64)
    if not np.array_equal(got, np.flatnonzero(lower)):
        rep.add("TRISOLVE_FWD_SET",
                "executed forward entries are not exactly L's",
                got=len(got), want=int(lower.sum()))
    bad = wmax[:n] >= rmin[:n]
    if np.any(bad):
        c = int(np.flatnonzero(bad)[0])
        rep.add("TRISOLVE_FWD_RACE",
                f"x[{c}] written at step {int(wmax[c])} but read at step "
                f"{int(rmin[c])}", col=c, n_bad=int(bad.sum()))

    # backward sweep: step t divides its level columns first (sequential in
    # the step body), then its updates read x[cols] / write x[rows]
    bsteps = _trisolve_steps(bwd_groups, 5)
    t_div = np.full(n + 1, -1, dtype=np.int64)
    n_div = np.zeros(n + 1, dtype=np.int64)
    ents = []
    for t, (lcols, ldiag, rows, cols, vidx) in enumerate(bsteps):
        if (np.any((lcols < 0) | (lcols > n))
                or np.any((ldiag < 0) | (ldiag > nnz))
                or np.any((vidx < 0) | (vidx > nnz))
                or np.any((rows < 0) | (rows > n))
                or np.any((cols < 0) | (cols > n))):
            rep.add("TRISOLVE_BWD_SET", "executed index out of range", step=t)
            return rep
        mc = lcols != n
        lc = lcols[mc]
        if np.any(ldiag[mc] != diag_idx[lc]):
            rep.add("TRISOLVE_BWD_SET",
                    "division diagonal is not the column's diag_idx", step=t)
        t_div[lc] = t
        n_div[lc] += 1
        m = vidx != nnz
        r, c, v = rows[m], cols[m], vidx[m]
        bad = (indices[v] != r) | (cols_of[v] != c) | (r >= c)
        if np.any(bad):
            rep.add("TRISOLVE_BWD_SET",
                    "executed entry disagrees with the U entry it indexes",
                    step=t, n_bad=int(bad.sum()))
        ents.append((r, c, v, np.full(len(v), t, dtype=np.int64)))
    if np.any(n_div[:n] != 1):
        rep.add("TRISOLVE_BWD_SET",
                "some column is divided more or less than once",
                n_bad=int((n_div[:n] != 1).sum()))
    if ents:
        r = np.concatenate([e[0] for e in ents])
        c = np.concatenate([e[1] for e in ents])
        v = np.concatenate([e[2] for e in ents])
        ts = np.concatenate([e[3] for e in ents])
    else:
        r = c = v = ts = np.zeros(0, dtype=np.int64)
    if not np.array_equal(np.sort(v), np.flatnonzero(upper)):
        rep.add("TRISOLVE_BWD_SET",
                "executed backward entries are not exactly strict U's",
                got=len(v), want=int(upper.sum()))
    bad = (t_div[c] > ts) | (t_div[c] < 0) | (ts >= t_div[r])
    if np.any(bad):
        i = int(np.flatnonzero(bad)[0])
        rep.add("TRISOLVE_BWD_RACE",
                f"update ({int(r[i])}, {int(c[i])}) at step {int(ts[i])} "
                f"races divisions at steps {int(t_div[r[i]])} (row) / "
                f"{int(t_div[c[i]])} (col)",
                n_bad=int(bad.sum()))
    return rep
