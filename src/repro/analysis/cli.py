"""Sweep the benchmark matrix zoo through the plan sanitizer.

    python -m repro.analysis.cli [--matrices rajat12_like,grid64]
                                 [--scale 1.0] [--engines gp,vectorized]
                                 [--variants default,nofuse,nodense]
                                 [--level full] [--reach-trials 8] [--seed 0]

Builds every (matrix, symbolic engine, executor variant) combination and
runs :func:`repro.analysis.verify_glu` on it — the same preprocessing the
benchmark harness applies (zero-free diagonal + fill-reducing ordering), so
the verified plans are exactly the plans the benchmarks execute.  Exits
nonzero if any case reports a violation.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

# (name, scale factor) — mirrors benchmarks/common.BENCH_MATRICES, which is
# not importable from an installed tree (benchmarks/ is repo-only)
ZOO = [
    ("rajat12_like", 1.0),
    ("circuit_2_like", 0.5),
    ("grid64", 0.5),
    ("memplus_like", 0.1),
    ("asic_like_10k", 0.15),
]

# executor variants: (tag, fuse_buckets, dense_tail)
VARIANTS = {
    "default": (True, True),
    "nofuse": (False, True),
    "nodense": (True, False),
}


def zoo_matrix(name: str, scale: float):
    """One suite matrix after the paper's Fig. 5 preprocessing."""
    from repro.core import fill_reducing_ordering, zero_free_diagonal
    from repro.sparse import make_suite_matrix

    A = make_suite_matrix(name, scale=scale)
    rp = zero_free_diagonal(A)
    A = A.permute(rp, np.arange(A.n, dtype=np.int64))
    perm = fill_reducing_ordering(A, "auto")
    return A.permute(perm, perm)


def run_case(A, engine: str, variant: str, *, level: str,
             reach_trials: int, seed: int):
    from repro.analysis import verify_glu
    from repro.core import GLU

    fuse, dense = VARIANTS[variant]
    glu = GLU(A, symbolic=engine, fuse_buckets=fuse, dense_tail=dense)
    return verify_glu(glu, level, reach_trials=reach_trials, seed=seed)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--matrices", default=",".join(n for n, _ in ZOO),
                    help="comma-separated zoo names (default: all)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="extra scale multiplier on the zoo sizes")
    ap.add_argument("--engines", default="gp,vectorized",
                    help="comma-separated symbolic engines")
    ap.add_argument("--variants", default=",".join(VARIANTS),
                    help=f"comma-separated executor variants of {list(VARIANTS)}")
    ap.add_argument("--level", choices=("plan", "full"), default="full")
    ap.add_argument("--reach-trials", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # repro.analysis (hence jax) is already imported by the time `python -m
    # repro.analysis.cli` reaches this module, so the JAX_ENABLE_X64 env
    # default would come too late — flip the config at runtime instead
    import jax

    jax.config.update("jax_enable_x64", True)

    names = [s for s in args.matrices.split(",") if s]
    engines = [s for s in args.engines.split(",") if s]
    variants = [s for s in args.variants.split(",") if s]
    for v in variants:
        if v not in VARIANTS:
            ap.error(f"unknown variant {v!r}; pick from {list(VARIANTS)}")
    scales = dict(ZOO)

    n_bad = 0
    for name in names:
        if name not in scales:
            ap.error(f"unknown zoo matrix {name!r}; pick from "
                     f"{[n for n, _ in ZOO]}")
        A = zoo_matrix(name, scales[name] * args.scale)
        for engine in engines:
            for variant in variants:
                t0 = time.perf_counter()
                rep = run_case(A, engine, variant, level=args.level,
                               reach_trials=args.reach_trials, seed=args.seed)
                dt = time.perf_counter() - t0
                tag = f"{name}(n={A.n}) {engine}/{variant}"
                if rep.ok:
                    print(f"OK   {tag}: {len(rep.checks)} checks "
                          f"[{dt:.1f}s]", flush=True)
                else:
                    n_bad += 1
                    print(f"FAIL {tag}: {sorted(rep.codes)} [{dt:.1f}s]",
                          flush=True)
                    for v in rep.violations[:5]:
                        print(f"     {v}", flush=True)
    if n_bad:
        print(f"{n_bad} case(s) FAILED verification", flush=True)
        return 1
    print("all cases verified", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
