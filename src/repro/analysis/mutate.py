"""Mutation corruptors for the verifier's own test suite.

Each mutator injects ONE known violation class into a (copied) golden plan
and returns the codes :func:`~repro.analysis.verify_plan` is guaranteed to
raise for it — the fuzz suite then asserts zero false negatives (every
injected corruption flagged with its code) and zero false positives
(golden plans stay clean).  Collateral codes beyond the guaranteed set are
expected: corrupting levels also desynchronises segments, and that is a
real violation too.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.dependency import Levelization, dependencies_exact
from ..core.plan import FactorizePlan

__all__ = ["MUTATIONS", "mutate_plan", "merge_executor_steps"]

MUTATIONS = (
    "swap_levels",
    "fuse_dependent_pair",
    "scatter_oob",
    "scatter_collision",
    "truncate_reach",
    "corrupt_triple",
    "drop_norm",
)


def _copy_plan(fplan: FactorizePlan) -> FactorizePlan:
    """Independent deep copy: mutations must never leak into the golden
    plan (it is reused across fuzz cases)."""
    kw = {}
    for f in dataclasses.fields(fplan):
        v = getattr(fplan, f.name)
        if isinstance(v, np.ndarray):
            v = v.copy()
        kw[f.name] = v
    kw["levels"] = Levelization(fplan.levels.levels.copy(),
                                fplan.levels.order.copy(),
                                fplan.levels.level_ptr.copy())
    kw["segments"] = [dataclasses.replace(s, cols=np.asarray(s.cols).copy())
                      for s in fplan.segments]
    return FactorizePlan(**kw)


def _relevelize(levels: np.ndarray) -> Levelization:
    order = np.argsort(levels, kind="stable").astype(np.int32)
    nlev = int(levels.max()) + 1 if len(levels) else 0
    counts = np.bincount(levels, minlength=nlev)
    ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return Levelization(levels.astype(np.int32), order, ptr)


def _pick_exact_edge(fplan: FactorizePlan, rng):
    src, dst = dependencies_exact(fplan)
    if not len(src):
        raise ValueError("plan has no dependency edges to corrupt")
    i = int(rng.integers(0, len(src)))
    return int(src[i]), int(dst[i])


def mutate_plan(fplan: FactorizePlan, kind: str, rng):
    """Return ``(mutated_plan, guaranteed_codes, info)`` for one mutation
    class.  ``rng`` is a ``numpy.random.Generator``."""
    p = _copy_plan(fplan)
    info = {}
    if kind == "swap_levels":
        s, d = _pick_exact_edge(p, rng)
        lev = p.levels.levels.astype(np.int64)
        ls, ld = int(lev[s]), int(lev[d])
        lev2 = lev.copy()
        lev2[lev == ls] = ld
        lev2[lev == ld] = ls
        p.levels = _relevelize(lev2)
        info.update(src=s, dst=d)
        return p, frozenset({"RACE_LEVEL_ORDER"}), info
    if kind == "fuse_dependent_pair":
        s, d = _pick_exact_edge(p, rng)
        lev = p.levels.levels.astype(np.int64)
        lev[d] = lev[s]
        p.levels = _relevelize(lev)
        info.update(src=s, dst=d)
        return p, frozenset({"RACE_INTRA_LEVEL"}), info
    if kind == "scatter_oob":
        i = int(rng.integers(0, len(p.a_scatter)))
        p.a_scatter[i] = p.nnz + 3
        info.update(slot=i)
        return p, frozenset({"SCATTER_OOB"}), info
    if kind == "scatter_collision":
        if len(p.a_scatter) < 2:
            raise ValueError("need >= 2 A entries for a collision")
        i = int(rng.integers(1, len(p.a_scatter)))
        p.a_scatter[i] = p.a_scatter[i - 1]
        info.update(slot=i)
        return p, frozenset({"SCATTER_COLLISION"}), info
    if kind == "truncate_reach":
        return _truncate_reach(p, rng, info)
    if kind == "corrupt_triple":
        if not len(p.didx):
            raise ValueError("plan has no update triples")
        i = int(rng.integers(0, len(p.didx)))
        # lidx[i] is a valid in-range entry of the SOURCE column — never
        # the destination column the didx slot must address
        p.didx[i] = p.lidx[i]
        info.update(triple=i)
        return p, frozenset({"TRIPLE_INCONSISTENT"}), info
    if kind == "drop_norm":
        if not len(p.norm_idx):
            raise ValueError("plan has no normalisation entries")
        i = int(rng.integers(0, len(p.norm_idx)))
        p.norm_idx[i] = p.nnz
        info.update(slot=i)
        return p, frozenset({"NORM_OOB"}), info
    raise ValueError(f"unknown mutation {kind!r}; one of {MUTATIONS}")


def _truncate_reach(p: FactorizePlan, rng, info):
    """Drop one L-adjacency entry.  Always REACH_ADJ_MISMATCH; when the
    dropped row is reachable from the seed column ONLY through the dropped
    edge, seeding the closure there also guarantees REACH_UNDER — the
    search below prefers such a column and reports it in ``info``."""
    ptr = p.l_adj_ptr.astype(np.int64)
    counts = np.diff(ptr)
    cands = np.flatnonzero(counts > 0)
    if not len(cands):
        raise ValueError("plan has no L adjacency to truncate")
    indptr = p.indptr.astype(np.int64)
    indices = p.indices.astype(np.int64)

    def l_rows(j):
        s, e = int(indptr[j]), int(indptr[j + 1])
        rows = indices[s:e]
        return rows[rows > j]

    def reachable_without(seed_col, dropped):
        seen = set()
        stack = [int(r) for r in l_rows(seed_col) if r != dropped]
        while stack:
            j = stack.pop()
            if j in seen:
                continue
            seen.add(j)
            stack.extend(int(r) for r in l_rows(j))
        return dropped in seen

    order = rng.permutation(cands)
    col = int(order[0])
    guaranteed = frozenset({"REACH_ADJ_MISMATCH"})
    for j in order.tolist():
        dropped = int(p.l_adj_rows[ptr[j + 1] - 1])
        if not reachable_without(j, dropped):
            col = j
            guaranteed = frozenset({"REACH_ADJ_MISMATCH", "REACH_UNDER"})
            break
    e = int(ptr[col + 1]) - 1
    p.l_adj_rows = np.delete(p.l_adj_rows, e)
    p.l_adj_ptr = ptr.copy()
    p.l_adj_ptr[col + 1:] -= 1
    info.update(seed_col=col, seed_sets=[[col]])
    return p, guaranteed, info


def merge_executor_steps(fact):
    """Fuse two dependent scan steps of a built factorizer schedule into one
    flat step — the bucket-merge bug class ``verify_executor`` exists to
    catch.  Returns ``(kinds, group_arrays, guaranteed_codes)`` or ``None``
    when no scan group spans an exact dependency edge (tiny schedules)."""
    plan = fact.plan
    src, dst = dependencies_exact(plan)
    lev = plan.levels.levels.astype(np.int64)
    # edges between adjacent levels, keyed by source level
    adj = set()
    for s, d in zip(lev[src], lev[dst]):
        if d == s + 1:
            adj.add(int(s))
    level = 0
    for gi, (kind, arrs) in enumerate(zip(fact._kinds, fact._group_arrays)):
        if kind == "dense":
            break
        if kind in ("flat", "pallas"):
            level += 1
            continue
        K = int(np.asarray(arrs[0]).shape[0])
        for k in range(K - 1):
            if (level + k) not in adj:
                continue
            a = [np.asarray(x) for x in arrs]
            merged = tuple(
                np.concatenate([x[k], x[k + 1]])[None, :] for x in a)
            new_kinds, new_arrays = [], []
            for gj, (kd, ar) in enumerate(zip(fact._kinds,
                                              fact._group_arrays)):
                if gj != gi:
                    new_kinds.append(kd)
                    new_arrays.append(ar)
                    continue
                if k > 0:
                    head = tuple(x[:k] for x in a)
                    new_kinds.append("scan" if k > 1 else "flat")
                    new_arrays.append(head)
                new_kinds.append("flat")
                new_arrays.append(merged)
                if k + 2 < K:
                    tail = tuple(x[k + 2:] for x in a)
                    new_kinds.append("scan" if K - k - 2 > 1 else "flat")
                    new_arrays.append(tail)
            return (tuple(new_kinds), tuple(new_arrays),
                    frozenset({"EXEC_RACE"}))
        level += K
    return None
