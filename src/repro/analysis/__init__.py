"""Static analysis of symbolic plans and built executor schedules.

The subsystem proves — from first principles, against the filled matrix
pattern — that a :class:`~repro.core.plan.FactorizePlan` and the schedules
compiled from it are safe to run:

* :func:`verify_plan` — recomputes the column dependency DAG from the
  pattern (the *exact* hazard set of the level-synchronous executor, via
  :func:`~repro.core.dependency.dependencies_exact`) and checks the
  levelization against it, plus every index array the plan carries
  (normalisation entries, update triples, A-scatter map, triangular-solve
  schedules, reach closures).
* :func:`verify_executor` / :func:`verify_trisolver` — walk the *built*
  post-bucketing schedule groups step by step with an exact write/read
  timing model, so bucket fusion and the dense tail are verified as
  executed, not as planned.
* :func:`audit_factorize` / :func:`audit_trisolve` — static jaxpr audit of
  the fused single-dispatch runners: no host callbacks, donation contract
  honoured, one dispatch.
* :func:`verify_glu` — all of the above over a built :class:`~repro.core.
  api.GLU`; this is what the ``GLU(verify=...)`` knob runs.

Findings come back as a :class:`VerifyReport` of coded
:class:`Violation` records (closed vocabulary in :data:`CODES`);
:mod:`repro.analysis.mutate` provides the corruptors the fuzz suite uses
to prove the detector has no false negatives.

Run ``python -m repro.analysis.cli`` to sweep the benchmark matrix zoo.
"""
from __future__ import annotations

from .invariants import verify_plan
from .jaxpr_audit import CALLBACK_PRIMITIVES, audit_factorize, audit_trisolve
from .mutate import MUTATIONS, merge_executor_steps, mutate_plan
from .report import CODES, PlanVerificationError, VerifyReport, Violation
from .schedule import verify_executor, verify_trisolver

__all__ = [
    "CODES",
    "CALLBACK_PRIMITIVES",
    "MUTATIONS",
    "PlanVerificationError",
    "VerifyReport",
    "Violation",
    "audit_factorize",
    "audit_trisolve",
    "merge_executor_steps",
    "mutate_plan",
    "verify_executor",
    "verify_glu",
    "verify_plan",
    "verify_trisolver",
]


def verify_glu(glu, level: str = "full", *, reach_trials: int = 8,
               seed: int = 0) -> VerifyReport:
    """Verify a built :class:`~repro.core.api.GLU` instance.

    ``level="plan"`` checks the symbolic plan only; ``"full"`` additionally
    walks the built factorizer and trisolver schedules and audits the fused
    runners' jaxprs.  Returns the merged :class:`VerifyReport`; raising on
    violations is the caller's choice (``GLU(verify=...)`` raises).
    """
    if level not in ("plan", "full"):
        raise ValueError(f"level must be 'plan' or 'full', got {level!r}")
    rep = verify_plan(glu.symbolic_plan, reach_trials=reach_trials, seed=seed)
    if level == "full":
        rep.merge(verify_executor(glu._factorizer))
        rep.merge(verify_trisolver(glu._solver))
        rep.merge(audit_factorize(glu._factorizer))
        rep.merge(audit_trisolve(glu._solver))
    return rep
