"""Structured verification results.

Every check in the analysis subsystem reports through a
:class:`VerifyReport`: a flat list of :class:`Violation` records plus the
names of the checks that ran.  Reports are cheap append-only containers —
checks never raise on a finding; callers decide via
:meth:`VerifyReport.raise_if_violated` (the ``GLU(verify=...)`` knob does).

Violation codes are a closed vocabulary (see ``CODES``) so tests and CI can
assert on *which* invariant broke, not just that one did.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Violation", "VerifyReport", "PlanVerificationError", "CODES"]

# code -> one-line meaning; the closed violation vocabulary
CODES = {
    # pattern / plan shape
    "PATTERN_MALFORMED": "CSC pattern arrays are not a valid sorted pattern",
    "DIAG_MISMATCH": "diag_idx does not point at the diagonal entries",
    "LEVELS_MALFORMED": "levels/order/level_ptr are mutually inconsistent",
    # schedule races (static, against the recomputed dependency DAG)
    "RACE_INTRA_LEVEL": "a dependency edge connects two same-level columns",
    "RACE_LEVEL_ORDER": "a dependency edge points level-backward",
    # normalisation arrays
    "NORM_OOB": "normalisation index outside [0, nnz)",
    "NORM_MISMATCH": "norm_idx/norm_diag disagree with the pattern's L entries",
    # update triples
    "TRIPLE_OOB": "update-triple index outside [0, nnz)",
    "TRIPLE_INCONSISTENT": "lidx/uidx/didx/dst_col rows+cols disagree",
    "TRIPLE_ORDER": "triples not sorted by (level, destination column)",
    "TRIPLE_SET_MISMATCH": "update-triple multiset differs from the pattern's",
    # A-value scatter map
    "SCATTER_OOB": "a_scatter slot outside [0, nnz)",
    "SCATTER_COLLISION": "a_scatter maps two A entries to one filled slot",
    "SCATTER_MISMATCH": "a_scatter target coordinates differ from A's",
    # triangular-solve schedules
    "TRISOLVE_FWD_RACE": "forward-solve entry reads a not-yet-final x",
    "TRISOLVE_FWD_SET": "forward-solve entry set differs from L's",
    "TRISOLVE_BWD_RACE": "backward-solve entry reads a not-yet-final x",
    "TRISOLVE_BWD_SET": "backward-solve entry/column set differs from U's",
    # reach closures
    "REACH_ADJ_MISMATCH": "plan DAG adjacency differs from the pattern's",
    "REACH_UNDER": "reach closure under-approximates (drops trisolve work)",
    "REACH_OVER": "reach closure over-approximates the true closure",
    # executed-schedule walk (post-bucketing groups)
    "EXEC_PAD_OOB": "group index outside [0, nnz] (nnz is the drop slot)",
    "EXEC_RACE": "an executed step writes an entry at/after a consuming read",
    "EXEC_SOURCE_ORDER": "an update fires before its source column is normal",
    "EXEC_NORM_COVERAGE": "executed normalisations differ from the plan's",
    "EXEC_UPDATE_COVERAGE": "executed update triples differ from the plan's",
    "EXEC_DENSE_TAIL": "dense-tail position map disagrees with the pattern",
    # jaxpr audit of the fused runners
    "AUDIT_CALLBACK": "fused program contains a host callback primitive",
    "AUDIT_DONATION": "buffer-donation contract of the runner not honoured",
    "AUDIT_DISPATCH": "whole-schedule execution is not a single dispatch",
}


@dataclasses.dataclass
class Violation:
    """One broken invariant.  ``context`` carries small structured details
    (offending indices, counts) for tests and CLI output."""

    code: str
    message: str
    context: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown violation code {self.code!r}")

    def __str__(self) -> str:
        ctx = ""
        if self.context:
            parts = ", ".join(f"{k}={v}" for k, v in self.context.items())
            ctx = f" [{parts}]"
        return f"{self.code}: {self.message}{ctx}"


class PlanVerificationError(RuntimeError):
    """Raised by ``raise_if_violated`` / ``GLU(verify=...)`` on findings."""

    def __init__(self, report: "VerifyReport"):
        self.report = report
        lines = [str(v) for v in report.violations[:10]]
        extra = len(report.violations) - len(lines)
        if extra > 0:
            lines.append(f"... and {extra} more")
        super().__init__(
            "plan verification failed with "
            f"{len(report.violations)} violation(s):\n  " + "\n  ".join(lines))


@dataclasses.dataclass
class VerifyReport:
    """Outcome of one verification run: which checks ran, what they found."""

    checks: list = dataclasses.field(default_factory=list)
    violations: list = dataclasses.field(default_factory=list)

    # per-code cap on recorded examples; further findings only bump the
    # count in the first record's context (keeps reports bounded on
    # badly corrupted plans)
    MAX_PER_CODE = 8

    def ran(self, check: str) -> None:
        if check not in self.checks:
            self.checks.append(check)

    def add(self, code: str, message: str, **context) -> None:
        n = sum(1 for v in self.violations if v.code == code)
        if n >= self.MAX_PER_CODE:
            for v in self.violations:
                if v.code == code:
                    v.context["suppressed"] = v.context.get("suppressed", 0) + 1
                    break
            return
        self.violations.append(Violation(code, message, context))

    def merge(self, other: "VerifyReport") -> "VerifyReport":
        for c in other.checks:
            self.ran(c)
        self.violations.extend(other.violations)
        return self

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def codes(self) -> frozenset:
        return frozenset(v.code for v in self.violations)

    def raise_if_violated(self) -> "VerifyReport":
        if self.violations:
            raise PlanVerificationError(self)
        return self

    def summary(self) -> dict:
        """Small JSON-able digest — what ``solve_info['verify_report']``
        carries."""
        return {
            "ok": self.ok,
            "n_checks": len(self.checks),
            "n_violations": len(self.violations),
            "codes": sorted(self.codes),
        }

    def __str__(self) -> str:
        head = (f"VerifyReport: {len(self.checks)} checks, "
                f"{len(self.violations)} violation(s)")
        if self.ok:
            return head + " — OK"
        return head + "\n" + "\n".join(f"  {v}" for v in self.violations)
