"""The paper's own benchmark configuration: matrix suites + solver knobs."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GLUConfig:
    suite: str = "grid64"          # key into repro.sparse.SUITES
    ordering: str = "auto"
    symbolic: str = "auto"
    dtype: str = "float64"
    fuse_levels: bool = True
    use_pallas: bool = False
    panel_threshold: int = 16      # paper: stream mode engages at level size 16


CONFIG = GLUConfig()
