"""StableLM-2 3B-class [hf:stabilityai/stablelm-2-1_6b scaled; unverified].

32L dense decoder, MHA (kv == heads == 32), partial rotary (25%),
LayerNorm, SwiGLU d_ff=6912, vocab 50304.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    act="swiglu",
    norm="layernorm",
    rotary_pct=0.25,
    rope_theta=10_000.0,
    seq_shard=True,
)
