"""Qwen2.5-3B [hf:Qwen/Qwen2.5 series; hf-verified family].

36L dense decoder, GQA 16 q / 2 kv heads, QKV bias, SwiGLU d_ff=11008,
RMSNorm, rope_theta 1e6, tied embeddings, vocab 151936.
kv heads (2) < TP degree (16): kv projections replicate across the model
axis (standard MQA/GQA practice) while q stays sharded.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    seq_shard=True,  # §Perf h2b: 2.2x bound-term win
)
