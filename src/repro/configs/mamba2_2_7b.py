"""Mamba2-2.7B [arXiv:2405.21060; unverified].

64L attention-free SSM (SSD / state-space duality), d_model 2560,
d_state 128, expand 2 (d_inner 5120), head dim 64 -> 80 ssm heads,
conv4 depthwise frontend per block, vocab 50280 (padded 50432).
Fully sub-quadratic -> long_500k eligible.
GLU3.0 applicability: SSD solves its structured (semiseparable) system by
a chunked scan, not LU — inapplicable, per DESIGN.md §Arch-applicability.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,          # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    norm="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    sub_quadratic=True,
)
