"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b; unverified].

24L dense decoder, MHA 32 heads, partial rotary 25%, LayerNorm,
SwiGLU d_ff=5632, vocab 100352.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    act="swiglu",
    norm="layernorm",
    rotary_pct=0.25,
    seq_shard=True,
)
