"""Whisper-base [arXiv:2212.04356; unverified].

Encoder-decoder, 6+6L, d_model 512, 8 heads (MHA), GELU d_ff=2048,
LayerNorm, vocab 51865 (padded to 51968).  Conv audio frontend is a STUB:
input_specs() supplies precomputed frame embeddings (B, 1500, d_model).
Decoder "seq_len" follows the assigned LM shapes; long_500k skipped
(quadratic decoder).  Model is 74M params -> attention TP off (replicate),
only FFN/vocab shard over the model axis.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
    attn_tp=False,
)
