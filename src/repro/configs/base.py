"""Model configuration system.

Every assigned architecture is a :class:`ModelConfig`; reduced smoke
variants are derived with :meth:`ModelConfig.reduced`.  Vocab sizes are
padded to a multiple of 256 so the vocab axis is always divisible by the
model-parallel degree (Megatron-style padding; noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]

VOCAB_PAD = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # --- attention ---------------------------------------------------------
    attention: str = "full"       # full | swa | mla | none
    window: int = 0               # swa window size
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0       # stablelm uses partial rotary (0.25)
    # --- MLA (deepseek-v2) ---------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- mlp -----------------------------------------------------------------
    act: str = "swiglu"           # swiglu | geglu | gelu | relu2
    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1            # apply MoE at layers where i % moe_every == moe_offset
    moe_groups: int = 0           # GShard-style local dispatch groups (0/1 = global)
    moe_offset: int = 0
    first_dense: int = 0          # first k layers always dense (deepseek)
    capacity_factor: float = 1.25
    # --- hybrid / ssm ----------------------------------------------------------
    attn_every: int = 0           # jamba: layer i is attention iff i % attn_every == attn_offset
    attn_offset: int = 0
    ssm_state: int = 0            # mamba2 d_state
    ssm_head_dim: int = 64        # mamba2 P
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0       # whisper
    encoder_seq: int = 0          # fixed source length (whisper: 1500)
    # --- frontends (stubs per the brief) -------------------------------------
    frontend: str = "none"        # none | audio_stub | vision_stub
    frontend_tokens: int = 0      # vision: patch tokens replacing prefix
    # --- misc -----------------------------------------------------------------
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- distribution knobs (per-arch defaults; launcher may override) -------
    attn_tp: bool = True          # shard heads over model axis
    fsdp: bool = False            # shard weight dim0 over data axis (big models)
    remat: bool = True
    remat_policy: str = "full"    # full | dots (save matmul outputs)
    scan_layers: bool = True      # lax.scan over the periodic layer pattern
    seq_shard: bool = False       # Megatron-style sequence parallelism (rules["seq"]="model")
    sub_quadratic: bool = False   # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    def is_attn_layer(self, i: int) -> bool:
        if self.attention == "none":
            return False
        if self.attn_every:
            return i % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.n_experts or i < self.first_dense:
            return False
        return i % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        from ..models.model import param_specs
        import numpy as np

        specs = param_specs(self)
        total = 0
        for leaf in _leaves(specs):
            total += int(np.prod(leaf[0]))
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        from ..models.model import param_specs
        import numpy as np

        inactive = 0
        for path, leaf in _leaves_with_path(param_specs(self)):
            if "experts" in path:
                frac = 1.0 - (self.top_k / self.n_experts)
                inactive += int(np.prod(leaf[0]) * frac)
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale = {
            "num_layers": min(self.num_layers, 2 if not self.attn_every else max(2, self.attn_every)),
            "d_model": 64,
            "num_heads": 4,
            "num_kv_heads": min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            "d_ff": 128,
            "vocab_size": 512,
            "head_dim": 16,
            "window": min(self.window, 32) if self.window else 0,
            "kv_lora_rank": 32 if self.kv_lora_rank else 0,
            "qk_nope_head_dim": 16 if self.qk_nope_head_dim else 0,
            "qk_rope_head_dim": 8 if self.qk_rope_head_dim else 0,
            "v_head_dim": 16 if self.v_head_dim else 0,
            "n_experts": min(self.n_experts, 4) if self.n_experts else 0,
            "top_k": min(self.top_k, 2) if self.top_k else 0,
            # dropless capacity (E/K) so smoke tests are deterministic
            "capacity_factor": (min(self.n_experts, 4) / min(self.top_k, 2))
            if self.n_experts else self.capacity_factor,
            "moe_d_ff": 64 if self.moe_d_ff else 0,
            "first_dense": min(self.first_dense, 1),
            "ssm_state": min(self.ssm_state, 16) if self.ssm_state else 0,
            "ssm_head_dim": 16 if self.ssm_state else self.ssm_head_dim,
            "encoder_layers": min(self.encoder_layers, 2),
            "encoder_seq": min(self.encoder_seq, 16) if self.encoder_seq else 0,
            "frontend_tokens": min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            "dtype": "float32",
            "fsdp": False,
            "remat": False,
        }
        return dataclasses.replace(self, **scale)


def _leaves(tree):
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _leaves(v)
    elif isinstance(tree, list):
        for v in tree:
            yield from _leaves(v)
    else:
        yield tree


def _leaves_with_path(tree, path=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _leaves_with_path(v, f"{path}/{k}")
    elif isinstance(tree, list):
        for i, v in enumerate(tree):
            yield from _leaves_with_path(v, f"{path}/{i}")
    else:
        yield path, tree


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
