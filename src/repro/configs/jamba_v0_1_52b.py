"""Jamba-v0.1 52B [arXiv:2403.19887; hf-verified].

32L hybrid: attention every 8th layer (1:7 attn:mamba), MoE (16 experts,
top-2) every other layer.  GQA 32 q / 8 kv on attention layers; Mamba
(SSM) layers carry long context -> sub-quadratic, long_500k eligible.
GLU3.0 applicability: the SSM blocks solve semiseparable systems via the
SSD scan, NOT sparse LU — inapplicable, per DESIGN.md §Arch-applicability.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    act="swiglu",
    norm="rmsnorm",
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    fsdp=True,
    sub_quadratic=True,
    moe_groups=16,   # §Perf h1g: 1.8x bound-term win
    seq_shard=True,
)
