"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf-verified].

27L, MLA attention (kv_lora_rank=512, qk_nope 128 + qk_rope 64, v 128),
MoE with 64 routed experts top-6 + 2 shared experts, moe_d_ff=1408,
first layer dense (d_ff 10944 ~ brief's d_ff field covers the MoE expert
width; the dense first layer uses 8 * moe_d_ff).  Full (quadratic) MLA
attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=11264,            # dense first-layer FFN width (8 * 1408)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense=1,
    fsdp=True,
    moe_groups=16,   # §Perf h1d: local dispatch groups, 4.0x bound-term win
    seq_shard=True,  # §Perf h1e
)
