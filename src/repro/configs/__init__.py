"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeSpec
from .nemotron_4_340b import CONFIG as nemotron_4_340b
from .stablelm_3b import CONFIG as stablelm_3b
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from .whisper_base import CONFIG as whisper_base
from .deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .phi_3_vision_4_2b import CONFIG as phi_3_vision_4_2b
from .mamba2_2_7b import CONFIG as mamba2_2_7b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        nemotron_4_340b,
        stablelm_3b,
        qwen2_5_3b,
        stablelm_1_6b,
        jamba_v0_1_52b,
        whisper_base,
        deepseek_v2_lite_16b,
        mixtral_8x7b,
        phi_3_vision_4_2b,
        mamba2_2_7b,
    ]
}


def get_config(arch: str) -> ModelConfig:
    key = arch.replace("_", "-")
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[key]


def list_archs() -> list[str]:
    return sorted(REGISTRY)


def shape_cells(arch: str) -> list[str]:
    """The dry-run cells defined for this arch (brief-mandated skips)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "REGISTRY",
    "get_config",
    "list_archs",
    "shape_cells",
]
