"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf family].

phi3-mini backbone: 32L, d_model 3072, MHA 32 heads, SwiGLU d_ff=8192,
RMSNorm, vocab 32064 (padded 32256).  CLIP vision frontend is a STUB:
input_specs() supplies precomputed patch embeddings (B, 1024 [here 256],
d_model) which replace the first ``frontend_tokens`` positions of the
sequence.  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    act="swiglu",
    norm="rmsnorm",
    frontend="vision_stub",
    frontend_tokens=256,
    seq_shard=True,
)
