"""Mixtral-8x7B [arXiv:2401.04088; hf-verified].

32L, GQA 32 q / 8 kv, 8 experts top-2 SwiGLU d_ff=14336, RMSNorm,
sliding-window attention (brief: SWA; window 4096) -> KV cache bounded by
the window, decode is O(window): long_500k eligible with a rolling-buffer
cache.  8 experts < TP degree 16 -> experts replicate over the model axis
and the expert FFN dim shards instead (EP-inside-TP).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    attention="swa",
    window=4096,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    n_experts=8,
    top_k=2,
    moe_d_ff=14336,
    fsdp=True,
    sub_quadratic=True,
    moe_groups=16,   # §Perf h1f: 2.1x bound-term win
    seq_shard=True,
)
