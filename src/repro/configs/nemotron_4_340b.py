"""Nemotron-4-340B [arXiv:2402.16819; unverified].

96L dense decoder, GQA (96 q heads, 8 kv), squared-ReLU MLP (no gating),
d_ff = 4 * d_model, vocab 256000.  Largest assigned arch -> FSDP on.
GLU3.0 applicability: none (no sparse LU inside a dense transformer) — see
DESIGN.md §Arch-applicability.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    act="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    fsdp=True,
    remat_policy="dots",  # §Perf h3c/h3d: selective remat, fits HBM
    seq_shard=True,       # §Perf h3d: 1.5x bound-term win
)
