"""Mesh-sharded batched sweeps: scenario-sharding descriptor unit tests in
the 1-device main process, plus an 8-emulated-device subprocess proving the
sharded refactorize_solve is bit-identical to the single-device batched
path across the mode matrix (native f64, robust, sparse-only schedule,
native complex, planar complex) and that non-divisible batches pad/mask
correctly."""
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.distributed import make_scenario_sharding, make_sweep_mesh


def test_no_mesh_means_no_sharding():
    assert make_scenario_sharding(None) is None


def test_single_device_mesh_stays_unsharded():
    # a 1-device mesh resolves the scenario rule to shards of size 1, which
    # buys nothing — the factory declines rather than wrapping in shard_map
    assert make_scenario_sharding(make_sweep_mesh(1)) is None


def test_make_sweep_mesh_rejects_oversubscription():
    with pytest.raises(ValueError):
        make_sweep_mesh(jax.device_count() + 1)


def test_glu_with_single_device_mesh_is_noop():
    import jax.numpy as jnp

    from repro.core import GLU
    from repro.sparse import circuit_jacobian

    A = circuit_jacobian(60, avg_degree=4.0, seed=3)
    rng = np.random.default_rng(0)
    vals = np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(3, A.nnz)))
    rhs = rng.normal(size=(3, A.n))
    ref = GLU(A, dtype=jnp.float64).refactorize_solve(vals, rhs)
    glu = GLU(A, dtype=jnp.float64, mesh=make_sweep_mesh(1))
    assert glu.n_devices == 1
    got = glu.refactorize_solve(vals, rhs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    assert glu.solve_info["n_devices"] == 1
    assert glu.solve_info["batch_spec"] is None


def test_rhs_batch_mismatch_raises():
    import jax.numpy as jnp

    from repro.core import GLU
    from repro.sparse import circuit_jacobian

    A = circuit_jacobian(60, avg_degree=4.0, seed=3)
    glu = GLU(A, dtype=jnp.float64)
    vals = np.repeat(np.asarray(A.data)[None], 3, axis=0)
    glu.factorize_batched(vals)
    with pytest.raises(ValueError, match="does not match"):
        glu.solve_batched(np.zeros((2, A.n)))


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_ENABLE_X64"] = "1"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import GLU
from repro.distributed import make_scenario_sharding, make_sweep_mesh, psum_exact
from repro.sparse import circuit_jacobian

assert jax.device_count() == 8

A = circuit_jacobian(120, avg_degree=4.0, seed=7)
rng = np.random.default_rng(0)
B = 16
vals = np.asarray(A.data)[None] * (
    1.0 + 0.1 * rng.uniform(-1, 1, size=(B, A.nnz)))
rhs = rng.normal(size=(B, A.n))
cvals = vals * np.exp(1j * rng.uniform(-0.3, 0.3, size=vals.shape))
crhs = rhs + 1j * rng.normal(size=rhs.shape)

mesh8 = make_sweep_mesh(8)
mesh4 = make_sweep_mesh(4)

# scenario-sharding descriptor math on a real multi-device mesh
s4 = make_scenario_sharding(mesh4)
assert s4 is not None and s4.n_shards == 4
assert s4.pad(7) == 8 and s4.pad(8) == 8 and s4.pad(1) == 4
s8 = make_scenario_sharding(mesh8)
assert s8.n_shards == 8 and s8.descriptor != s4.descriptor

# psum_exact really reduces across all 8 shards, exactly
tot = shard_map(lambda v: psum_exact(jnp.sum(v), "data"), mesh=mesh8,
                in_specs=(P("data"),), out_specs=P(), check_rep=False)(
                    jnp.arange(8, dtype=jnp.int64))
assert int(tot) == 28, int(tot)

# mode matrix: sharded == single-device batched, bit for bit
CONFIGS = [
    ("f64_native", dict(dtype=jnp.float64), vals, rhs),
    ("f64_robust", dict(dtype=jnp.float64, static_pivot=1e-12, refine=2),
     vals, rhs),
    ("f64_sparse_only", dict(dtype=jnp.float64, dense_tail=False), vals, rhs),
    ("c128_native", dict(dtype=jnp.complex128), cvals, crhs),
    ("c128_planar", dict(dtype=jnp.complex128, layout="planar"),
     cvals, crhs),
]
for name, kw, v, b in CONFIGS:
    g_ref = GLU(A, **kw)
    ref = g_ref.refactorize_solve(v, b)
    ref_info = g_ref.solve_info
    g = GLU(A, mesh=mesh8, **kw)
    got = g.refactorize_solve(v, b)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                  err_msg=name)
    info = g.solve_info
    assert info["n_devices"] == 8, (name, info)
    assert info["batch_spec"] == "PartitionSpec('data',)", (name, info)
    # sharding must not change the dispatch shape: one fused factorization
    # dispatch, and exactly as many solve dispatches as the single-device
    # path (refinement legitimately adds trisolve dispatches on both)
    assert info["n_dispatches"] == ref_info["n_dispatches"] == 1, (name, info)
    assert info["solve_dispatches"] == ref_info["solve_dispatches"], (
        name, info["solve_dispatches"], ref_info["solve_dispatches"])
    if "refine" not in kw:
        assert info["solve_dispatches"] == 1, (name, info)
    if "static_pivot" in kw:
        assert info["n_perturbed_global"] is not None
        assert int(info["n_perturbed_global"]) >= 0
        assert np.asarray(info["n_perturbed"]).shape == (B,)
    print("ok", name)

# padding: B=7 on a 4-device mesh pads to 8 and masks the pad row out of
# results and every per-matrix diagnostic
kw = dict(dtype=jnp.float64, static_pivot=1e-12, refine=2)
v7, b7 = vals[:7], rhs[:7]
ref = GLU(A, **kw).refactorize_solve(v7, b7)
g = GLU(A, mesh=mesh4, **kw)
got = g.refactorize_solve(v7, b7)
np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
assert got.shape == (7, A.n)
assert g.factorized_values_batched().shape[0] == 7
info = g.solve_info
assert info["n_devices"] == 4, info
for key in ("pivot_growth", "min_diag", "n_perturbed", "refine_iters"):
    assert np.asarray(info[key]).shape == (7,), (key, info[key])
print("ok padding_b7_d4")
print("SUBPROCESS_OK")
"""


def test_eight_device_sharded_sweep_integration():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, cwd=Path(__file__).resolve().parents[1],
                       timeout=570)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
