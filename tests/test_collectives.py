"""distributed.collectives: int8 quantisation round-trip bounds and the
compressed/exact psum helpers (single-device mesh in-process; the real
8-shard reduction is exercised by test_sharded_sweep's subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import (
    compressed_psum,
    dequantize_int8,
    psum_exact,
    quantize_int8,
)


def test_int8_round_trip_error_bounded_by_half_step():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    back = dequantize_int8(q, scale)
    # quantisation error is at most half a quantisation step
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_int8_round_trip_exact_on_grid_values():
    # values already on the int8 grid survive the round trip exactly
    x = jnp.asarray([-127.0, -1.0, 0.0, 1.0, 64.0, 127.0], jnp.float32)
    q, scale = quantize_int8(x)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, scale)),
                               np.asarray(x), rtol=1e-6, atol=1e-6)


def test_quantize_zero_vector():
    q, scale = quantize_int8(jnp.zeros(8, jnp.float32))
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(dequantize_int8(q, scale)) == 0.0)


def test_compressed_psum_single_shard_is_fake_quantize():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.linspace(-1.0, 1.0, 64, dtype=np.float32))
    out = shard_map(lambda v: compressed_psum(v, "data"), mesh=mesh,
                    in_specs=(P(),), out_specs=P(), check_rep=False)(x)
    q, scale = quantize_int8(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(dequantize_int8(q, scale)),
                               rtol=0, atol=1e-6)


def test_compressed_psum_tree_structure_preserved():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.ones((4, 4), jnp.float32),
            "b": jnp.full((4,), -2.0, jnp.float32)}
    out = shard_map(lambda t: compressed_psum(t, "data"), mesh=mesh,
                    in_specs=(P(),), out_specs=P(), check_rep=False)(tree)
    assert set(out) == {"w", "b"}
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, atol=1e-2)
    np.testing.assert_allclose(np.asarray(out["b"]), -2.0, atol=1e-1)


def test_psum_exact_integers_stay_exact():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"bumps": jnp.asarray(3, jnp.int64),
            "counts": jnp.asarray([1, 2, 3], jnp.int32)}
    out = shard_map(lambda t: psum_exact(t, "data"), mesh=mesh,
                    in_specs=(P(),), out_specs=P(), check_rep=False)(tree)
    assert int(out["bumps"]) == 3
    assert out["bumps"].dtype == jnp.int64
    np.testing.assert_array_equal(np.asarray(out["counts"]), [1, 2, 3])
