"""Per-architecture smoke tests (deliverable f): reduced config, one forward
and one train step on CPU, asserting output shapes and finiteness."""
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, shape_cells
from repro.models import forward_train, init_params
from repro.train import OptConfig, TrainConfig, init_opt_state, make_train_step


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                            jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


# the two heaviest configs go to the slow suite; every arch still compiles
# in tier-1 via test_full_config_divisibility
_SLOW_ARCHS = {"jamba-v0.1-52b", "deepseek-v2-lite-16b"}


def _smoke_archs():
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
            for a in list_archs()]


@pytest.mark.parametrize("arch", _smoke_archs())
def test_smoke_forward(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward_train(params, batch["tokens"], cfg,
                                {k: v for k, v in batch.items()
                                 if k not in ("tokens", "labels")} or None)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", _smoke_archs())
def test_smoke_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    opt_cfg = OptConfig(lr=1e-3, warmup=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig()))
    params2, opt2, metrics = step(params, opt, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


def test_all_archs_registered():
    assert len(list_archs()) == 10


def test_shape_cells_skips():
    """long_500k only for sub-quadratic archs (brief-mandated skips)."""
    long_ok = {a for a in list_archs() if "long_500k" in shape_cells(a)}
    assert long_ok == {"jamba-v0.1-52b", "mamba2-2.7b", "mixtral-8x7b"}
    total = sum(len(shape_cells(a)) for a in list_archs())
    assert total == 33


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_divisibility(arch):
    """Production TP degree (16) divides the sharded dims (or the rule
    resolver will replicate — verify the important ones do divide)."""
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    if cfg.d_ff:
        assert cfg.d_ff % 16 == 0
    if cfg.attn_tp and cfg.attention != "mla" and cfg.attention != "none":
        assert cfg.num_heads % 16 == 0
