"""Executor configuration matrix, oracle-backed.

Sweeps every executor knob combination — ``fuse_levels`` on/off,
``use_pallas`` on/off (interpret mode), ``dense_tail`` on/off, and each
``mode_override`` — against the sequential host oracle
``factorize_numpy`` on generated circuit-like matrices, and asserts by
name that every ``_Group`` kind (``scan``/``flat``/``pallas``/``dense``)
is exercised somewhere in the sweep.  The complex128 half of the matrix
runs the same sweep on planar re/im-plane storage, cross-checked against
the native-complex reference path and a scipy ``splu`` solve oracle.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    JaxFactorizer,
    build_plan,
    factorize_numpy,
    fill_reducing_ordering,
    symbolic_fillin_gp,
)
from repro.core.plan import MODE_FLAT, MODE_PANEL, MODE_SEGMENTED
from repro.sparse import circuit_jacobian, unpack_planes

OVERRIDES = [None, MODE_FLAT, MODE_SEGMENTED, MODE_PANEL]


@pytest.fixture(scope="module")
def problem():
    A = circuit_jacobian(130, avg_degree=4.0, seed=21)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    return A, plan, oracle


@pytest.fixture(scope="module")
def dense_problem():
    """mindeg-ordered larger instance whose trailing block goes dense."""
    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    return A, plan, oracle


@pytest.mark.parametrize("mode_override", OVERRIDES,
                         ids=[o or "auto" for o in OVERRIDES])
@pytest.mark.parametrize("use_pallas", [
    pytest.param(False, id="xla"),
    # interpret-mode Pallas sweeps are the suite's heaviest cells; the
    # xla cells plus test_pallas_executor_matches_oracle keep tier-1 honest
    pytest.param(True, id="pallas", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("fuse_levels", [False, True], ids=["nofuse", "fuse"])
def test_mode_matrix_matches_oracle(problem, fuse_levels, use_pallas,
                                    mode_override):
    A, plan, oracle = problem
    fx = JaxFactorizer(
        plan,
        dtype=jnp.float64,
        fuse_levels=fuse_levels,
        use_pallas=use_pallas,
        mode_override=mode_override,
        interpret=True,
    )
    if use_pallas and mode_override in (MODE_SEGMENTED, MODE_PANEL):
        # levels with updates must route through the Pallas kernel
        assert any(g.kind == "pallas" for g in fx._groups)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_mode_matrix_dense_tail(dense_problem, use_pallas):
    A, plan, oracle = dense_problem
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=True,
                       use_pallas=use_pallas, interpret=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    assert any(g.kind == "dense" for g in fx._groups)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


def test_dense_tail_off_has_no_dense_group(dense_problem):
    _, plan, _ = dense_problem
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=False)
    assert all(g.kind != "dense" for g in fx._groups)


def test_mode_rule_uses_update_volume(problem, dense_problem):
    """Fig. 10 criteria: a narrow level is PANEL only while its update
    volume stays small — on the generator matrices at least one narrow
    level carries enough update work to be (re)classified SEGMENTED, and
    genuinely light narrow levels stay PANEL."""
    pt = 16
    flipped = light_panels = 0
    for _, plan, _ in (problem, dense_problem):
        for seg in plan.segments:
            nc, nu = len(seg.cols), seg.n_upd
            if nc <= pt and nu > 32 * pt * nc:
                assert seg.mode == MODE_SEGMENTED, (nc, nu, seg.mode)
                flipped += 1
            elif nc <= pt:
                assert seg.mode == MODE_PANEL, (nc, nu, seg.mode)
                light_panels += 1
    # the column-count-only rule would have classified these PANEL
    assert flipped >= 1
    assert light_panels >= 1


def test_every_group_kind_exercised(problem, dense_problem):
    """The executor configuration space reaches every step kind by name
    (self-contained: builds its own factorizers, no cross-test state)."""
    _, plan, _ = problem
    _, dense_plan, _ = dense_problem
    kinds = set()
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=True)._groups)
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=False)._groups)
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, use_pallas=True)._groups)
    fx = JaxFactorizer(dense_plan, dtype=jnp.float64, dense_tail=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    kinds.update(g.kind for g in fx._groups)
    assert kinds >= {"scan", "flat", "pallas", "dense"}, kinds


# -- complex128 planar half of the matrix ---------------------------------
def _complexify(A, seed):
    rng = np.random.default_rng(seed)
    phase = np.exp(1j * rng.uniform(-np.pi, np.pi, A.nnz))
    return dataclasses.replace(A, data=A.data.astype(np.complex128) * phase)


@pytest.fixture(scope="module")
def complex_problem(problem):
    A, plan, _ = problem
    Ac = _complexify(A, 33)
    As = symbolic_fillin_gp(Ac)
    oracle = factorize_numpy(As, As.filled_csc(Ac).data)
    # the native-complex flat-XLA path is the bit-reference for planar
    native = np.asarray(JaxFactorizer(plan, dtype=jnp.complex128)
                        .factorize(Ac.data))
    return Ac, plan, oracle, native


@pytest.fixture(scope="module")
def complex_dense_problem(dense_problem):
    A, plan, _ = dense_problem
    Ac = _complexify(A, 34)
    As = symbolic_fillin_gp(Ac)
    oracle = factorize_numpy(As, As.filled_csc(Ac).data)
    return Ac, plan, oracle


@pytest.mark.parametrize("mode_override", OVERRIDES,
                         ids=[o or "auto" for o in OVERRIDES])
@pytest.mark.parametrize("use_pallas", [
    pytest.param(False, id="xla"),
    pytest.param(True, id="pallas", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("fuse_levels", [False, True], ids=["nofuse", "fuse"])
def test_mode_matrix_complex_planar(complex_problem, fuse_levels, use_pallas,
                                    mode_override):
    Ac, plan, oracle, native = complex_problem
    fx = JaxFactorizer(
        plan,
        dtype=jnp.complex128,
        layout="planar",
        fuse_levels=fuse_levels,
        use_pallas=use_pallas,
        mode_override=mode_override,
        interpret=True,
    )
    assert fx.layout.planar
    if use_pallas and mode_override in (MODE_SEGMENTED, MODE_PANEL):
        assert any(g.kind == "pallas" for g in fx._groups)
        assert fx.pallas_disabled_reason is None
    raw = fx.factorize(np.asarray(Ac.data))
    assert raw.shape == (len(oracle), 2)       # planes on device
    out = np.asarray(unpack_planes(raw))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(out, native, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("use_pallas", [
    pytest.param(False, id="xla"),
    pytest.param(True, id="pallas", marks=pytest.mark.slow),
])
def test_complex_planar_dense_tail(complex_dense_problem, use_pallas):
    Ac, plan, oracle = complex_dense_problem
    fx = JaxFactorizer(plan, dtype=jnp.complex128, layout="planar",
                       dense_tail=True, use_pallas=use_pallas, interpret=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    assert any(g.kind == "dense" for g in fx._groups)
    out = np.asarray(unpack_planes(fx.factorize(np.asarray(Ac.data))))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


def test_every_group_kind_exercised_planar(complex_problem,
                                           complex_dense_problem):
    """The planar executor reaches the same step-kind space as native."""
    _, plan, _, _ = complex_problem
    _, dense_plan, _ = complex_dense_problem
    def mk(p, **kw):
        return JaxFactorizer(p, dtype=jnp.complex128, layout="planar", **kw)

    kinds = set()
    kinds.update(g.kind for g in mk(plan, fuse_levels=True)._groups)
    kinds.update(g.kind for g in mk(plan, fuse_levels=False)._groups)
    kinds.update(g.kind for g in mk(plan, use_pallas=True)._groups)
    fx = mk(dense_plan, dense_tail=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    kinds.update(g.kind for g in fx._groups)
    assert kinds >= {"scan", "flat", "pallas", "dense"}, kinds


def test_complex_planar_solution_matches_scipy(complex_problem):
    """End-to-end planar solve against an external scipy splu oracle."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla

    from repro.core import GLU

    Ac, _, _, _ = complex_problem
    rng = np.random.default_rng(6)
    b = rng.standard_normal(Ac.n) + 1j * rng.standard_normal(Ac.n)
    g = GLU(Ac, dtype=jnp.complex128, use_pallas=True, refine=1)
    assert g.layout.name == "planar"
    x = np.asarray(g.solve(b))
    A = sp.csc_matrix((Ac.data, Ac.indices, Ac.indptr), shape=(Ac.n, Ac.n))
    x_ref = spla.splu(A.tocsc()).solve(b)
    np.testing.assert_allclose(x, x_ref, rtol=1e-9, atol=1e-11)
