"""Executor configuration matrix, oracle-backed.

Sweeps every executor knob combination — ``fuse_levels`` on/off,
``use_pallas`` on/off (interpret mode), ``dense_tail`` on/off, and each
``mode_override`` — against the sequential host oracle
``factorize_numpy`` on generated circuit-like matrices, and asserts by
name that every ``_Group`` kind (``scan``/``flat``/``pallas``/``dense``)
is exercised somewhere in the sweep.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    JaxFactorizer,
    build_plan,
    factorize_numpy,
    fill_reducing_ordering,
    symbolic_fillin_gp,
)
from repro.core.plan import MODE_FLAT, MODE_PANEL, MODE_SEGMENTED
from repro.sparse import circuit_jacobian

OVERRIDES = [None, MODE_FLAT, MODE_SEGMENTED, MODE_PANEL]


@pytest.fixture(scope="module")
def problem():
    A = circuit_jacobian(130, avg_degree=4.0, seed=21)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    return A, plan, oracle


@pytest.fixture(scope="module")
def dense_problem():
    """mindeg-ordered larger instance whose trailing block goes dense."""
    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    return A, plan, oracle


@pytest.mark.parametrize("mode_override", OVERRIDES,
                         ids=[o or "auto" for o in OVERRIDES])
@pytest.mark.parametrize("use_pallas", [
    pytest.param(False, id="xla"),
    # interpret-mode Pallas sweeps are the suite's heaviest cells; the
    # xla cells plus test_pallas_executor_matches_oracle keep tier-1 honest
    pytest.param(True, id="pallas", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("fuse_levels", [False, True], ids=["nofuse", "fuse"])
def test_mode_matrix_matches_oracle(problem, fuse_levels, use_pallas,
                                    mode_override):
    A, plan, oracle = problem
    fx = JaxFactorizer(
        plan,
        dtype=jnp.float64,
        fuse_levels=fuse_levels,
        use_pallas=use_pallas,
        mode_override=mode_override,
        interpret=True,
    )
    if use_pallas and mode_override in (MODE_SEGMENTED, MODE_PANEL):
        # levels with updates must route through the Pallas kernel
        assert any(g.kind == "pallas" for g in fx._groups)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["xla", "pallas"])
def test_mode_matrix_dense_tail(dense_problem, use_pallas):
    A, plan, oracle = dense_problem
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=True,
                       use_pallas=use_pallas, interpret=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    assert any(g.kind == "dense" for g in fx._groups)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


def test_dense_tail_off_has_no_dense_group(dense_problem):
    _, plan, _ = dense_problem
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=False)
    assert all(g.kind != "dense" for g in fx._groups)


def test_mode_rule_uses_update_volume(problem, dense_problem):
    """Fig. 10 criteria: a narrow level is PANEL only while its update
    volume stays small — on the generator matrices at least one narrow
    level carries enough update work to be (re)classified SEGMENTED, and
    genuinely light narrow levels stay PANEL."""
    pt = 16
    flipped = light_panels = 0
    for _, plan, _ in (problem, dense_problem):
        for seg in plan.segments:
            nc, nu = len(seg.cols), seg.n_upd
            if nc <= pt and nu > 32 * pt * nc:
                assert seg.mode == MODE_SEGMENTED, (nc, nu, seg.mode)
                flipped += 1
            elif nc <= pt:
                assert seg.mode == MODE_PANEL, (nc, nu, seg.mode)
                light_panels += 1
    # the column-count-only rule would have classified these PANEL
    assert flipped >= 1
    assert light_panels >= 1


def test_every_group_kind_exercised(problem, dense_problem):
    """The executor configuration space reaches every step kind by name
    (self-contained: builds its own factorizers, no cross-test state)."""
    _, plan, _ = problem
    _, dense_plan, _ = dense_problem
    kinds = set()
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=True)._groups)
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, fuse_levels=False)._groups)
    kinds.update(g.kind for g in
                 JaxFactorizer(plan, dtype=jnp.float64, use_pallas=True)._groups)
    fx = JaxFactorizer(dense_plan, dtype=jnp.float64, dense_tail=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    kinds.update(g.kind for g in fx._groups)
    assert kinds >= {"scan", "flat", "pallas", "dense"}, kinds
