"""Symbolic fill-in vs the scipy no-pivot splu oracle."""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import symbolic_fillin, symbolic_fillin_etree, symbolic_fillin_gp
from repro.sparse import circuit_jacobian, grid_laplacian, rc_ladder


def _pattern_matrix(As):
    return sp.csc_matrix(
        (np.ones(As.nnz), As.indices, As.indptr), shape=(As.n, As.n))


@pytest.mark.parametrize("gen,kw", [
    (circuit_jacobian, dict(n=120, avg_degree=4.0, seed=1)),
    (circuit_jacobian, dict(n=200, avg_degree=5.0, seed=2, asym=0.5)),
    (grid_laplacian, dict(nx=10, ny=10)),
    (rc_ladder, dict(n=64)),
])
def test_gp_fill_matches_scipy(gen, kw):
    A = gen(**kw)
    As = symbolic_fillin_gp(A)
    lu = spla.splu(A.to_scipy().tocsc(), permc_spec="NATURAL", diag_pivot_thresh=0.0)
    oracle = ((abs(lu.L) + abs(lu.U)) != 0).astype(np.int8)
    ours = (_pattern_matrix(As) != 0).astype(np.int8)
    missing = (oracle - ours) > 0
    assert missing.nnz == 0, "fill pattern must contain the oracle pattern"


def test_etree_is_superset_of_gp():
    A = circuit_jacobian(180, avg_degree=4.5, seed=3)
    gp = _pattern_matrix(symbolic_fillin_gp(A))
    et = _pattern_matrix(symbolic_fillin_etree(A))
    assert ((gp != 0).astype(int) - (et != 0).astype(int) > 0).nnz == 0


def test_scatter_map_roundtrip():
    A = circuit_jacobian(90, avg_degree=4.0, seed=4)
    As = symbolic_fillin(A, "gp")
    filled = As.filled_csc(A)
    assert np.allclose(abs(filled.to_scipy() - A.to_scipy()).max(), 0.0)


def test_dispatch_auto():
    A = circuit_jacobian(60, seed=5)
    assert symbolic_fillin(A, "auto").method == "gp"
