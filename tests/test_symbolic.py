"""Symbolic fill-in vs the scipy no-pivot splu oracle."""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import (
    symbolic_fillin,
    symbolic_fillin_etree,
    symbolic_fillin_gp,
    symbolic_fillin_vectorized,
)
from repro.core.symbolic import _scatter_map, _scatter_map_loop
from repro.sparse import circuit_jacobian, grid_laplacian, rc_ladder


def _pattern_matrix(As):
    return sp.csc_matrix(
        (np.ones(As.nnz), As.indices, As.indptr), shape=(As.n, As.n))


@pytest.mark.parametrize("gen,kw", [
    (circuit_jacobian, dict(n=120, avg_degree=4.0, seed=1)),
    (circuit_jacobian, dict(n=200, avg_degree=5.0, seed=2, asym=0.5)),
    (grid_laplacian, dict(nx=10, ny=10)),
    (rc_ladder, dict(n=64)),
])
def test_gp_fill_matches_scipy(gen, kw):
    A = gen(**kw)
    As = symbolic_fillin_gp(A)
    lu = spla.splu(A.to_scipy().tocsc(), permc_spec="NATURAL", diag_pivot_thresh=0.0)
    oracle = ((abs(lu.L) + abs(lu.U)) != 0).astype(np.int8)
    ours = (_pattern_matrix(As) != 0).astype(np.int8)
    missing = (oracle - ours) > 0
    assert missing.nnz == 0, "fill pattern must contain the oracle pattern"


def test_etree_is_superset_of_gp():
    A = circuit_jacobian(180, avg_degree=4.5, seed=3)
    gp = _pattern_matrix(symbolic_fillin_gp(A))
    et = _pattern_matrix(symbolic_fillin_etree(A))
    assert ((gp != 0).astype(int) - (et != 0).astype(int) > 0).nnz == 0


def test_scatter_map_roundtrip():
    A = circuit_jacobian(90, avg_degree=4.0, seed=4)
    As = symbolic_fillin(A, "gp")
    filled = As.filled_csc(A)
    assert np.allclose(abs(filled.to_scipy() - A.to_scipy()).max(), 0.0)


def test_dispatch_auto():
    A = circuit_jacobian(60, seed=5)
    assert symbolic_fillin(A, "auto").method == "gp"


def test_dispatch_vectorized():
    A = circuit_jacobian(60, seed=5)
    assert symbolic_fillin(A, "vectorized").method == "vectorized"


@pytest.mark.parametrize("gen,kw", [
    (circuit_jacobian, dict(n=120, avg_degree=4.0, seed=1)),
    (circuit_jacobian, dict(n=200, avg_degree=5.0, seed=2, asym=0.5)),
    (grid_laplacian, dict(nx=10, ny=10)),
    (rc_ladder, dict(n=64)),
])
def test_vectorized_fill_matches_scipy(gen, kw):
    """The frontier-batched engine passes the same oracle check as GP."""
    A = gen(**kw)
    As = symbolic_fillin_vectorized(A)
    lu = spla.splu(A.to_scipy().tocsc(), permc_spec="NATURAL", diag_pivot_thresh=0.0)
    oracle = ((abs(lu.L) + abs(lu.U)) != 0).astype(np.int8)
    ours = (_pattern_matrix(As) != 0).astype(np.int8)
    assert ((oracle - ours) > 0).nnz == 0


@pytest.mark.parametrize("engine", [symbolic_fillin_gp, symbolic_fillin_etree,
                                    symbolic_fillin_vectorized])
def test_scatter_map_vectorized_equals_loop(engine):
    """Satellite: the flat-searchsorted scatter map is entry-for-entry equal
    to the per-column loop it replaced, on every engine's fill."""
    A = circuit_jacobian(150, avg_degree=4.5, n_rails=2, seed=6)
    As = engine(A)
    np.testing.assert_array_equal(
        _scatter_map(A, As.indptr, As.indices),
        _scatter_map_loop(A, As.indptr, As.indices))


def test_scatter_map_rejects_missing_entries():
    A = circuit_jacobian(50, avg_degree=4.0, seed=8)
    # "filled" pattern = A's own pattern minus column 0's first entry: that
    # A entry can no longer be located, and both implementations must agree
    indptr = A.indptr.astype(np.int64).copy()
    indptr[1:] -= 1
    indices = A.indices[1:]
    for fn in (_scatter_map, _scatter_map_loop):
        with pytest.raises(AssertionError):
            fn(A, indptr, indices)
