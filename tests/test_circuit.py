"""Circuit simulation: MNA assembly correctness + transient driver."""
import numpy as np
import pytest

from repro.circuit import Circuit, rc_grid_circuit, transient, transient_sweep


def test_resistor_divider_dc():
    """V source as Norton eq.: I=1A into node1, R1=1 to node2, R2=1 to gnd."""
    ckt = Circuit(3)
    ckt.add_resistor(1, 2, 1.0)
    ckt.add_resistor(2, 0, 1.0)
    ckt.add_current_source(0, 1, 1.0)  # 1A into node 1
    res = transient(ckt, t_end=0.01, dt=0.01)
    v = res.voltages[-1]
    np.testing.assert_allclose(v, [2.0, 1.0], atol=1e-9)


def test_rc_decay():
    """Single RC: step response toward I*R."""
    ckt = Circuit(2)
    ckt.add_resistor(1, 0, 2.0)
    ckt.add_capacitor(1, 0, 1.0)
    ckt.add_current_source(0, 1, 1.0)
    res = transient(ckt, t_end=20.0, dt=0.5)
    v_final = res.voltages[-1, 0]
    assert abs(v_final - 2.0) < 0.05    # -> I*R
    assert res.voltages[0, 0] < v_final  # monotone rise


def test_diode_clamps():
    ckt = Circuit(2)
    ckt.add_resistor(1, 0, 100.0)
    ckt.add_diode(1, 0)
    ckt.add_current_source(0, 1, 0.1)   # pushes node up; diode clamps ~0.6V
    res = transient(ckt, t_end=0.01, dt=0.01, max_newton=60)
    v = res.voltages[-1, 0]
    assert 0.3 < v < 0.9
    assert res.max_residual < 1e-6


def test_grid_transient_residuals():
    ckt = rc_grid_circuit(5, 5, with_diodes=True, seed=2)
    res = transient(ckt, t_end=0.03, dt=0.005)
    assert res.max_residual < 1e-8
    assert np.isfinite(res.voltages).all()
    # symbolic analysis done once, numeric factorization per Newton iter
    assert res.n_factorizations == res.newton_iters.sum()


def test_transient_sweep_matches_unbatched():
    """Lockstep batched Newton on one plan: the scale=1.0 copy must equal
    the single-circuit driver, and perturbed corners must differ."""
    ckt = rc_grid_circuit(4, 4, with_diodes=True, seed=1)
    ref = transient(ckt, t_end=0.02, dt=0.005)
    sw = transient_sweep(ckt, t_end=0.02, dt=0.005, scales=[0.9, 1.0, 1.1])
    assert sw.voltages.shape == (3, len(ref.times), ckt.n)
    np.testing.assert_allclose(sw.voltages[1], ref.voltages,
                               rtol=1e-8, atol=1e-10)
    assert np.abs(sw.voltages[0] - sw.voltages[2]).max() > 1e-5
    assert sw.max_residual < 1e-6
    # one batched factorization per lockstep Newton iterate
    assert sw.n_batched_factorizations == sw.newton_iters.sum()


@pytest.mark.slow
def test_transient_sweep_long():
    """Longer corner sweep (the Monte-Carlo workload) stays convergent."""
    ckt = rc_grid_circuit(6, 6, with_diodes=True, seed=4)
    scales = np.linspace(0.8, 1.2, 8)
    sw = transient_sweep(ckt, t_end=0.05, dt=0.002, scales=scales)
    assert np.isfinite(sw.voltages).all()
    assert sw.max_residual < 1e-6
    v_final = sw.voltages[:, -1, :]
    assert (v_final.max(axis=0) - v_final.min(axis=0)).max() > 1e-4


def test_transient_refined_consumes_solve_info():
    """The Newton loop inspects GLU.solve_info after every refined solve;
    on a healthy circuit every solve converges, so no re-scaling rebuild
    fires and the waveform matches the unrefined run."""
    ckt = rc_grid_circuit(4, 4, with_diodes=True, seed=2)
    ref = transient(ckt, t_end=0.01, dt=0.005)
    res = transient(ckt, t_end=0.01, dt=0.005, refine=2, static_pivot=1e-10)
    assert res.n_rescalings == 0
    assert np.isfinite(res.voltages).all()
    np.testing.assert_allclose(res.voltages, ref.voltages, rtol=1e-7,
                               atol=1e-9)


def test_assembly_pattern_reuse():
    ckt = rc_grid_circuit(4, 4, seed=3)
    pat = ckt.pattern()
    v = np.zeros(ckt.n)
    vals1, rhs1 = ckt.assemble(v, v, 1e-3, 0.0)
    vals2, rhs2 = ckt.assemble(v + 0.1, v, 1e-3, 0.1)
    assert vals1.shape == vals2.shape == (pat.nnz,)


def test_perturbed_copies_keep_ac_sources():
    """Regression: ``perturbed_copies`` used to drop ``ac_isources``, so AC
    excitation silently vanished from sweep copies."""
    from repro.circuit.simulate import perturbed_copies

    ckt = rc_grid_circuit(3, 3, with_diodes=False, seed=0)
    ckt.add_ac_current_source(2, 0, 0.5 - 0.25j)
    copies = perturbed_copies(ckt, [1.0, 2.0])
    v0 = np.zeros(ckt.n)
    freqs = [10.0, 1e3]
    _, rhs_orig = ckt.assemble_ac(v0, freqs)
    for c in copies:
        assert c.ac_isources == ckt.ac_isources
        _, rhs_copy = c.assemble_ac(v0, freqs)
        # the excitation is scale-independent: copies reproduce it exactly
        np.testing.assert_array_equal(rhs_copy, rhs_orig)
    assert np.abs(rhs_orig).max() > 0


def test_pattern_invalidated_by_post_pattern_mutation():
    """Regression: ``Circuit.pattern()`` cached the pattern and stamp maps
    forever, so ``add_*`` calls after the first ``pattern()`` were silently
    ignored by ``assemble``/``assemble_ac``."""
    ckt = Circuit(3)
    ckt.add_resistor(1, 0, 1.0)
    ckt.add_resistor(2, 0, 1.0)
    pat1 = ckt.pattern()
    v = np.zeros(ckt.n)
    vals1, _ = ckt.assemble(v, v, 0.0, 0.0)

    # every element builder must invalidate: the new resistor couples the
    # nodes, the capacitor/diode/sources stamp values and rhs
    ckt.add_resistor(1, 2, 2.0)
    pat2 = ckt.pattern()
    assert pat2.nnz > pat1.nnz
    vals2, _ = ckt.assemble(v, v, 0.0, 0.0)
    assert vals2.shape == (pat2.nnz,)
    A2 = np.zeros((ckt.n, ckt.n))
    cols = np.repeat(np.arange(ckt.n), np.diff(pat2.indptr))
    A2[pat2.indices, cols] = vals2
    np.testing.assert_allclose(A2, [[1.5, -0.5], [-0.5, 1.5]])

    ckt.add_current_source(0, 1, 1.0)
    _, rhs = ckt.assemble(v, v, 0.0, 0.0)
    assert rhs[0] == 1.0

    ckt.add_capacitor(2, 0, 1.0)
    nnz_before = ckt.pattern().nnz
    vals3, _ = ckt.assemble(v, v, 0.5, 0.0)
    assert vals3.shape == (nnz_before,)
    # C/dt = 2 landed on the new capacitor's diagonal
    d11 = ckt.pattern().value_index(1, 1)
    assert vals3[d11] == pytest.approx(1.5 + 2.0)

    ckt.add_ac_current_source(2, 0, 1.0)
    _, rhs_ac = ckt.assemble_ac(v, [10.0])
    assert rhs_ac[0, 1] == -1.0

    ckt.add_diode(1, 0)
    vals4, _ = ckt.assemble(v, v, 0.0, 0.0)
    assert vals4.shape == (ckt.pattern().nnz,)
