"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import dense_lu, segmented_accumulate
from repro.kernels.ref import dense_lu_ref, segmented_accumulate_ref
from repro.kernels.ops import spmv


@pytest.mark.parametrize("D,C,R", [
    (1, 128, 256),
    (4, 384, 256),
    (8, 512, 512),
    (3, 1024, 768),
    (2, 2048, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_segmented_accumulate(D, C, R, dtype, rng):
    cv = rng.normal(size=(D, C)).astype(dtype)
    cb = rng.normal(size=(D, R)).astype(dtype)
    dl = rng.integers(0, C + 64, size=(D, R)).astype(np.int32)  # some padded
    cb = np.where(dl < C, cb, 0.0).astype(dtype)
    out_k = np.asarray(segmented_accumulate(jnp.asarray(cv), jnp.asarray(cb),
                                            jnp.asarray(dl), interpret=True))
    out_r = np.asarray(segmented_accumulate_ref(jnp.asarray(cv), jnp.asarray(cb),
                                                jnp.asarray(dl)))
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(out_k, out_r, rtol=tol, atol=tol)


def test_segmented_accumulate_duplicate_indices(rng):
    """Many updates hitting the same slot must sum (the GPU-atomics case)."""
    D, C, R = 2, 128, 512
    cv = np.zeros((D, C), np.float64)
    cb = np.ones((D, R))
    dl = np.zeros((D, R), np.int32)  # all hit slot 0
    out = np.asarray(segmented_accumulate(jnp.asarray(cv), jnp.asarray(cb),
                                          jnp.asarray(dl), interpret=True))
    assert np.allclose(out[:, 0], R)
    assert np.allclose(out[:, 1:], 0.0)


@pytest.mark.parametrize("N,block", [(128, 128), (256, 128), (256, 64), (384, 128)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dense_lu(N, block, dtype, rng):
    a = (rng.normal(size=(N, N)) + N * np.eye(N)).astype(dtype)
    lu_k = np.asarray(dense_lu(jnp.asarray(a), block=block, interpret=True))
    lu_r = np.asarray(dense_lu_ref(jnp.asarray(a.astype(np.float64))))
    tol = 5e-3 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(lu_k, lu_r, rtol=tol, atol=tol)
    # LU actually factors A
    L = np.tril(lu_k.astype(np.float64), -1) + np.eye(N)
    U = np.triu(lu_k.astype(np.float64))
    np.testing.assert_allclose(L @ U, a.astype(np.float64), rtol=1e-2 if dtype == np.float32 else 1e-8,
                               atol=1e-2 if dtype == np.float32 else 1e-8)


def test_spmv_matches_scipy(rng):
    import scipy.sparse as sp

    from repro.sparse import circuit_jacobian

    A = circuit_jacobian(300, avg_degree=4.0, seed=3)
    S = A.to_scipy().tocsr()
    x = rng.normal(size=A.n)
    row_ids = np.repeat(np.arange(A.n), np.diff(S.indptr))
    y = np.asarray(spmv(jnp.asarray(row_ids), jnp.asarray(S.indices),
                        jnp.asarray(S.data), jnp.asarray(x), n_rows=A.n))
    np.testing.assert_allclose(y, S @ x, rtol=1e-10)
