"""Numerical-robustness subsystem: full MC64 scaling, pivot-growth
diagnostics, static pivot perturbation, and batched iterative refinement.

The acceptance scenario: on an ill-conditioned generator matrix
(condition >= 1e10) where the unscaled pipeline's residual exceeds 1e-6,
the scaled + refined float64 path reaches componentwise backward error
<= 1e-12 in both single and batched modes, with ``GLU.solve_info``
reporting pivot growth, perturbation count, and refinement iterations.
"""
from itertools import permutations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GLU, factorize_numpy, max_product_matching
from repro.sparse import circuit_jacobian, ill_conditioned_jacobian
from repro.sparse.csc import csc_from_coo, csc_to_dense

BERR_TOL = 1e-12


# --------------------------------------------------------------------------
# MC64 max-product matching + scaling
# --------------------------------------------------------------------------

def test_max_product_matching_invariants():
    """Duff-Koster guarantee: |Dr A Dc| <= 1 everywhere, == 1 on the
    matched entries, and the matching is a permutation."""
    for seed in range(6):
        A = ill_conditioned_jacobian(40 + 10 * seed, decades=8.0, seed=seed)
        perm, Dr, Dc = max_product_matching(A)
        assert sorted(perm) == list(range(A.n))
        rows, cols, vals = A.to_coo()
        scaled = np.abs(Dr[rows] * vals * Dc[cols.astype(np.int64)])
        assert scaled[np.abs(vals) > 0].max() <= 1 + 1e-8
        D = csc_to_dense(csc_from_coo(A.n, perm[rows], cols,
                                      Dr[rows] * vals * Dc[cols.astype(np.int64)]))
        np.testing.assert_allclose(np.abs(np.diag(D)), 1.0, atol=1e-8)


def test_max_product_matching_optimal_small():
    """Exhaustive check: the matching maximises the diagonal product."""
    for seed in range(8):
        A = ill_conditioned_jacobian(7, decades=6.0, seed=seed + 100)
        perm, _, _ = max_product_matching(A)
        D = csc_to_dense(A)
        inv = np.argsort(perm)
        ours = np.abs(np.prod([D[inv[j], j] for j in range(A.n)]))
        best = max(np.abs(np.prod([D[p[j], j] for j in range(A.n)]))
                   for p in permutations(range(A.n)))
        assert ours >= best * (1 - 1e-9)


def test_max_product_matching_rejects_singular():
    # a column that is structurally present but numerically all-zero
    A = circuit_jacobian(20, avg_degree=3.0, seed=1)
    data = np.asarray(A.data).copy()
    s, e = int(A.indptr[4]), int(A.indptr[5])
    data[s:e] = 0.0
    from repro.sparse.csc import CSC

    with pytest.raises(ValueError):
        max_product_matching(CSC(A.n, A.indptr, A.indices, data))


# --------------------------------------------------------------------------
# Acceptance scenario: ill-conditioned matrix, single + batched
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hard_problem():
    A = ill_conditioned_jacobian(200, decades=12.0, seed=3)
    assert np.linalg.cond(csc_to_dense(A)) >= 1e10
    return A


def test_unscaled_pipeline_fails(hard_problem):
    """The pre-robustness pipeline (structural matching only) loses more
    than 6 digits on this matrix — the bug class this PR detects/repairs."""
    A = hard_problem
    b = np.random.default_rng(0).normal(size=A.n)
    g = GLU(A, mc64="structural", dtype=jnp.float64)
    x = g.factorize().solve(b)
    assert g.residual(b, x) > 1e-6


def test_scaled_refined_single(hard_problem):
    A = hard_problem
    b = np.random.default_rng(0).normal(size=A.n)
    g = GLU(A, dtype=jnp.float64, refine=5)
    x = g.factorize().solve(b)
    info = g.solve_info
    assert info["backward_error"] <= BERR_TOL
    assert info["converged"] is True or info["converged"] == np.True_
    assert info["pivot_growth"] > 0
    assert info["refine_iters"] >= 0
    assert np.isfinite(x).all()


def test_scaled_refined_batched(hard_problem):
    A = hard_problem
    rng = np.random.default_rng(1)
    B = 4
    batch = np.asarray(A.data)[None] * (
        1.0 + 0.05 * rng.uniform(-1, 1, size=(B, A.nnz)))
    bs = rng.normal(size=(B, A.n))
    g = GLU(A, dtype=jnp.float64, refine=5)
    xs = g.factorize_batched(batch).solve_batched(bs)
    info = g.solve_info
    assert xs.shape == (B, A.n)
    assert info["batched"] is True
    assert info["backward_error"].shape == (B,)
    assert (info["backward_error"] <= BERR_TOL).all()
    assert np.asarray(info["converged"]).all()
    assert info["pivot_growth"].shape == (B,)
    assert info["refine_iters"].shape == (B,)


# --------------------------------------------------------------------------
# Static pivot perturbation + refinement recovery
# --------------------------------------------------------------------------

def test_tiny_pivot_detected_then_repaired():
    """Structurally nonsingular, numerically tiny pivots with scaling OFF:
    the growth stats must expose the blow-up, and the static-pivot guard +
    refinement must recover full accuracy on the same matrix."""
    A = ill_conditioned_jacobian(150, decades=0.0, tiny_pivots=3, seed=5)
    b = np.random.default_rng(0).normal(size=A.n)

    plain = GLU(A, mc64="none", dtype=jnp.float64)
    x_plain = plain.factorize().solve(b)
    info = plain.solve_info
    assert info["pivot_growth"] > 1e6          # detected, not silent
    assert info["min_diag"] < 1e-10
    assert plain.residual(b, x_plain) > 1e-8   # and genuinely wrong

    guarded = GLU(A, mc64="none", dtype=jnp.float64,
                  static_pivot=1e-10, refine=10)
    x = guarded.factorize().solve(b)
    info = guarded.solve_info
    assert info["n_perturbed"] >= 1
    assert info["backward_error"] <= BERR_TOL
    assert guarded.residual(b, x) <= 1e-12


def test_mc64_rematches_tiny_pivots():
    """Full MC64 moves large entries onto the diagonal, so the same matrix
    factorizes with small growth and no perturbations at all."""
    A = ill_conditioned_jacobian(150, decades=0.0, tiny_pivots=3, seed=5)
    b = np.random.default_rng(0).normal(size=A.n)
    g = GLU(A, dtype=jnp.float64, static_pivot=1e-10, refine=5)
    x = g.factorize().solve(b)
    info = g.solve_info
    assert info["pivot_growth"] < 1e3
    assert info["n_perturbed"] == 0
    assert info["backward_error"] <= BERR_TOL
    assert g.residual(b, x) <= 1e-12


def test_batched_perturbation_counts_per_matrix():
    """One tiny-pivot matrix and one healthy matrix in the same batch:
    the (B,) perturbation counts must tell them apart."""
    A = circuit_jacobian(80, avg_degree=3.5, seed=9)
    healthy = np.asarray(A.data).copy()
    sick = healthy.copy()
    sick[A.value_index(0, 0)] = 1e-300
    # ordering="none" keeps column 0 first: no incoming updates can repair
    # its diagonal before elimination, so the guard must fire
    g = GLU(A, mc64="none", ordering="none", dtype=jnp.float64,
            static_pivot=1e-10)
    g.factorize_batched(np.stack([sick, healthy]))
    info = g.solve_info
    assert info["n_perturbed"][0] >= 1
    assert info["n_perturbed"][1] == 0


def test_perturb_diags_padding_never_counted():
    """Padded diag slots must not inflate the bump count even when tau > 1
    (the out-of-range gather fills with 1.0, which |1.0| < tau would hit)."""
    from repro.kernels.ops import perturb_diags

    vals = jnp.asarray(np.full(10, 100.0))
    diag_idx = jnp.asarray(np.array([0, 1, 10, 10], dtype=np.int32))
    out, cnt = perturb_diags(vals, diag_idx, jnp.asarray(1000.0))
    assert int(cnt) == 2                       # only the two real slots
    assert np.asarray(out)[:2].tolist() == [1000.0, 1000.0]
    assert (np.asarray(out)[2:] == 100.0).all()


# --------------------------------------------------------------------------
# Growth stats vs host oracle
# --------------------------------------------------------------------------

def test_growth_stats_match_numpy_oracle():
    A = ill_conditioned_jacobian(120, decades=6.0, seed=11)
    g = GLU(A, dtype=jnp.float64)
    g.factorize()
    info = g.solve_info
    # oracle on the exact system the device factorizes (scaled + permuted)
    filled = g.pattern.filled_csc(g._A_perm)
    lu = factorize_numpy(g.pattern, filled.data)
    a_max = np.abs(np.asarray(g._A_perm.data)).max()
    np.testing.assert_allclose(info["pivot_growth"],
                               np.abs(lu).max() / a_max, rtol=1e-12)
    np.testing.assert_allclose(info["min_diag"],
                               np.abs(lu[g.plan.diag_idx]).min(), rtol=1e-12)


def test_growth_stats_batched_match_single():
    A = circuit_jacobian(100, avg_degree=4.0, seed=13)
    rng = np.random.default_rng(2)
    batch = np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(3, A.nnz)))
    g = GLU(A, dtype=jnp.float64)
    g.factorize_batched(batch)
    batched = g.solve_info
    for i in range(3):
        g.factorize(batch[i])
        single = g.solve_info
        np.testing.assert_allclose(batched["pivot_growth"][i],
                                   single["pivot_growth"], rtol=1e-12)
        np.testing.assert_allclose(batched["min_diag"][i],
                                   single["min_diag"], rtol=1e-12)


# --------------------------------------------------------------------------
# solve_info contract + facade plumbing
# --------------------------------------------------------------------------

def test_solve_info_contract_single():
    A = circuit_jacobian(60, avg_degree=3.5, seed=17)
    g = GLU(A, dtype=jnp.float64)
    assert g.solve_info is None
    b = np.ones(A.n)
    g.factorize()
    info = g.solve_info
    assert {"batched", "pivot_growth", "min_diag", "n_perturbed",
            "refine_iters", "backward_error", "converged"} <= set(info)
    assert info["batched"] is False
    assert info["n_perturbed"] is None         # guard off
    g.solve(b)                                 # refine=0 default
    info = g.solve_info
    assert info["refine_iters"] == 0
    assert info["backward_error"] is None and info["converged"] is None
    g.solve(b, refine=2)
    info = g.solve_info
    assert isinstance(info["backward_error"], float)
    assert isinstance(info["refine_iters"], int)


def test_refactorize_solve_single_collapses_info():
    """The single-matrix convenience form of refactorize_solve must leave
    scalar (not shape-(1,)) diagnostics, per the solve_info contract."""
    A = circuit_jacobian(60, avg_degree=3.5, seed=21)
    b = np.random.default_rng(7).normal(size=A.n)
    g = GLU(A, dtype=jnp.float64, refine=2)
    g.refactorize_solve(np.asarray(A.data), b)
    info = g.solve_info
    assert info["batched"] is False
    assert isinstance(info["backward_error"], float)
    assert isinstance(info["converged"], bool)
    assert isinstance(info["refine_iters"], int)
    assert isinstance(info["pivot_growth"], float)


def test_stale_factor_invalidation():
    """Regression: a fresh single factorization must invalidate the batched
    factor cache and vice versa — never solve with other values' factors."""
    A = circuit_jacobian(70, avg_degree=3.5, seed=19)
    rng = np.random.default_rng(3)
    batch = np.asarray(A.data)[None] * (
        1.0 + 0.3 * rng.uniform(-1, 1, size=(2, A.nnz)))
    bs = rng.normal(size=(2, A.n))
    g = GLU(A, dtype=jnp.float64)
    g.factorize_batched(batch)
    g.factorize()                              # fresh single values
    with pytest.raises(RuntimeError):
        g.solve_batched(bs)                    # batched cache is gone
    g.factorize_batched(batch)                 # fresh batched values
    with pytest.raises(RuntimeError):
        g.solve(bs[0])                         # single cache is gone too


def test_facade_plumbs_executor_knobs():
    """dense_tail / dense_tail_density / mode_override / interpret /
    static_pivot reach JaxFactorizer through the public facade."""
    from repro.core import fill_reducing_ordering

    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    g = GLU(A, ordering="none", dtype=jnp.float64, dense_tail=True,
            dense_tail_density=0.2, static_pivot=1e-10, interpret=True)
    fx = g._factorizer
    assert fx.static_pivot == 1e-10
    # this generator/ordering pair is known to produce a dense tail (same
    # instance as the executor-level dense-tail tests) — the facade must
    # reach it, that's the point of the plumbing
    assert fx.dense_tail_info is not None
    assert any(grp.kind == "dense" for grp in fx._groups)
    b = np.random.default_rng(4).normal(size=A.n)
    x = g.factorize().solve(b, refine=2)
    assert g.solve_info["backward_error"] <= BERR_TOL
    assert g.residual(b, x) < 1e-10

    A_small = circuit_jacobian(100, avg_degree=4.0, seed=24)
    g2 = GLU(A_small, dtype=jnp.float64, mode_override="flat")
    assert all(grp.mode == "flat" for grp in g2._factorizer._groups)
    b2 = np.random.default_rng(6).normal(size=A_small.n)
    x2 = g2.factorize().solve(b2)
    assert g2.residual(b2, x2) < 1e-10


def test_refinement_float32_improves():
    """Refinement also helps the paper's float32 mode: a couple of sweeps
    reach float32-level componentwise backward error."""
    A = circuit_jacobian(120, avg_degree=4.0, seed=23)
    b = np.random.default_rng(5).normal(size=A.n)
    g = GLU(A, dtype=jnp.float32, refine=4)
    g.factorize().solve(b)
    assert g.solve_info["backward_error"] <= 4 * np.finfo(np.float32).eps


# --------------------------------------------------------------------------
# chunked refinement: no per-sweep device->host sync
# --------------------------------------------------------------------------

def test_refined_solve_single_sync_in_common_case(monkeypatch):
    """Regression (perf): refinement used to force one device->host sync per
    sweep.  The common k<=2 case must now pay exactly ONE transfer, counted
    both by the returned ``host_syncs`` and by intercepting the actual
    ``jax.device_get`` calls."""
    import jax

    A = circuit_jacobian(150, avg_degree=4.0, seed=9)
    glu = GLU(A, refine=2).factorize()
    b = np.random.default_rng(1).standard_normal(A.n)

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: calls.extend([1]) or real(x))
    x = glu.solve(b)
    monkeypatch.undo()

    info = glu.solve_info
    assert info["converged"]
    assert info["host_syncs"] == 1
    # one berr/iters transfer inside refinement; the only other device_get
    # is the final np.asarray(x) (which goes through jnp, not device_get)
    assert len(calls) == 1
    assert glu.residual(b, x) < 1e-10


def test_refined_solve_sync_count_scales_with_chunks():
    """tol=0 can never be met, so max_iter sweeps all run: with the default
    sync_every=2 that is ceil(max_iter / 2) transfers — not max_iter."""
    A = circuit_jacobian(120, avg_degree=4.0, seed=10)
    glu = GLU(A, refine=5, refine_tol=0.0).factorize()
    b = np.random.default_rng(2).standard_normal(A.n)
    glu.solve(b)
    info = glu.solve_info
    assert not info["converged"]
    assert info["refine_iters"] == 5           # every sweep was applied
    assert info["host_syncs"] == 3             # chunks of 2, 2, 1


def test_refined_batched_sync_and_masking():
    """Batched refinement: converged rows stop accumulating iterations (the
    device-side mask) while the whole batch still costs one sync per chunk."""
    A = circuit_jacobian(100, avg_degree=4.0, seed=11)
    rng = np.random.default_rng(3)
    B = 3
    batch = np.asarray(A.data)[None, :] * (
        1.0 + 0.01 * rng.uniform(-1, 1, size=(B, A.nnz)))
    b = rng.standard_normal((B, A.n))
    glu = GLU(A, refine=2)
    glu.refactorize_solve(batch, b)
    info = glu.solve_info
    assert np.asarray(info["converged"]).all()
    assert info["host_syncs"] == 1
    assert np.asarray(info["refine_iters"]).shape == (B,)
    assert np.asarray(info["backward_error"]).max() <= glu.refine_tol


def test_refined_masked_iters_match_early_stop_semantics():
    """``refine_iters`` counts only sweeps applied while still above
    tolerance — identical numbers to the old sync-per-sweep early-stop."""
    A = ill_conditioned_jacobian(150, decades=10.0, seed=4)
    glu = GLU(A, refine=4).factorize()
    b = np.random.default_rng(4).standard_normal(A.n)
    glu.solve(b)
    info = glu.solve_info
    assert info["converged"]
    assert 0 <= info["refine_iters"] <= 4
    # a converged solve re-run with a larger budget must not iterate more
    glu2 = GLU(A, refine=8).factorize()
    glu2.solve(b)
    assert glu2.solve_info["refine_iters"] == info["refine_iters"]
