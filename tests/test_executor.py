"""Single-dispatch schedule executor: bucketed ragged fusion + whole-schedule
jit.

Contracts under test:
 * bit-identity — the bucketed-fusion + whole-schedule-jit executor returns
   BIT-identical factors/solutions to the unfused per-level reference
   (``fuse_levels=False, jit_schedule=False``) across the full mode matrix:
   flat/segmented/panel overrides, pallas, dense tail, single + batched,
   real + complex, robust (static pivot) + plain;
 * dispatch accounting — the fused path issues exactly ONE device dispatch
   per factorization / triangular solve (``last_n_dispatches``, surfaced as
   ``solve_info["n_dispatches"]`` / ``["solve_dispatches"]``);
 * executable-cache reuse — a second executor on the same plan pulls the
   SAME runner object from the process-wide cache (compiles nothing);
 * sparse-RHS full-reach shortcut — a pattern whose reach closure covers
   every column reuses the full schedule object instead of building a
   redundant pruned twin.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    GLU,
    ExecutableCache,
    JaxFactorizer,
    JaxTriangularSolver,
    build_plan,
    default_executable_cache,
    factorize_numpy,
    fill_reducing_ordering,
    symbolic_fillin_gp,
)
from repro.core.plan import MODE_FLAT, MODE_PANEL, MODE_SEGMENTED, choose_buckets
from repro.sparse import circuit_jacobian, unpack_planes


@pytest.fixture(scope="module")
def problem():
    A = circuit_jacobian(220, avg_degree=4.0, seed=7)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    return A, plan, oracle


@pytest.fixture(scope="module")
def dense_problem():
    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    return A, plan


def _reference(plan, dtype, **kw):
    """The seed executor: per-level, per-group-dispatch."""
    return JaxFactorizer(plan, dtype=dtype, fuse_levels=False,
                         jit_schedule=False, **kw)


# -- bucket ladder unit behavior -------------------------------------------

def test_choose_buckets_waste_bound():
    sizes = [3, 5, 9, 17, 33, 200, 1000]
    ladder = choose_buckets(sizes, max_waste=4.0)
    assert list(ladder) == sorted(set(ladder))
    # every pow2 pad lands on a bucket within the waste bound
    from repro.core.plan import bucketize, pow2_pad
    for s in sizes:
        p = pow2_pad(s)
        b = bucketize(p, ladder)
        assert p <= b <= 4.0 * p


def test_bucketing_reduces_groups(problem):
    _, plan, _ = problem
    exact = JaxFactorizer(plan, dtype=jnp.float64, fuse_buckets=False)
    bucketed = JaxFactorizer(plan, dtype=jnp.float64)
    assert bucketed.n_groups <= exact.n_groups
    # the long narrow schedules this repo targets collapse substantially
    assert bucketed.n_groups < plan.num_levels // 4


# -- bit-identity matrix ----------------------------------------------------

CONFIGS = [
    pytest.param(dict(), id="default"),
    pytest.param(dict(mode_override=MODE_FLAT), id="flat"),
    pytest.param(dict(mode_override=MODE_SEGMENTED), id="segmented"),
    pytest.param(dict(mode_override=MODE_PANEL), id="panel"),
    pytest.param(dict(use_pallas=True), id="pallas"),
    pytest.param(dict(static_pivot=1e-10), id="robust"),
    pytest.param(dict(use_pallas=True, static_pivot=1e-10),
                 id="pallas-robust"),
    pytest.param(dict(fuse_buckets=False), id="nobuckets"),
]


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.complex128],
                         ids=["real", "complex"])
@pytest.mark.parametrize("kw", CONFIGS)
def test_fused_bit_identical_single(problem, kw, dtype):
    A, plan, _ = problem
    a = np.asarray(A.data, dtype=np.dtype(dtype))
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        a = a + 1j * np.linspace(-1, 1, len(a))
    ref = _reference(plan, dtype, **kw)
    out_ref = np.asarray(ref.factorize(a))
    fx = JaxFactorizer(plan, dtype=dtype, **kw)
    out = np.asarray(fx.factorize(a))
    assert out.tobytes() == out_ref.tobytes()
    assert fx.last_n_dispatches == 1
    assert ref.last_n_dispatches > 10 * fx.last_n_dispatches


@pytest.mark.parametrize("kw", CONFIGS)
def test_fused_bit_identical_batched(problem, kw):
    A, plan, _ = problem
    rng = np.random.default_rng(3)
    batch = rng.standard_normal((3, A.nnz))
    ref = _reference(plan, jnp.float64, **kw)
    out_ref = np.stack([np.asarray(ref.factorize(v)) for v in batch])
    fx = JaxFactorizer(plan, dtype=jnp.float64, **kw)
    out = np.asarray(fx.factorize_batched(batch))
    assert out.tobytes() == out_ref.tobytes()
    assert fx.last_n_dispatches == 1


def test_fused_bit_identical_dense_tail(dense_problem):
    A, plan = dense_problem
    a = np.asarray(A.data)
    for kw in (dict(dense_tail=True), dict(dense_tail=True, use_pallas=True),
               dict(dense_tail=True, static_pivot=1e-10)):
        ref = _reference(plan, jnp.float64, **kw)
        if ref.dense_tail_info is None:
            pytest.skip("no dense tail found for this instance")
        fx = JaxFactorizer(plan, dtype=jnp.float64, **kw)
        assert np.asarray(fx.factorize(a)).tobytes() == \
            np.asarray(ref.factorize(a)).tobytes()
        # batched twin (always XLA dense LU on both paths)
        batch = np.stack([a, a * 0.5])
        out_b = np.asarray(fx.factorize_batched(batch))
        ref_b = np.stack([np.asarray(ref.factorize(v)) for v in batch])
        assert out_b.tobytes() == ref_b.tobytes()


def test_fused_filled_entry_matches(problem):
    """factorize_filled (pre-scattered values, donated) == factorize."""
    A, plan, _ = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    out = np.asarray(fx.factorize(A.data))
    vals = jnp.zeros(plan.nnz, dtype=jnp.float64
                     ).at[jnp.asarray(plan.a_scatter)].set(
                         jnp.asarray(A.data, dtype=jnp.float64))
    out2 = np.asarray(fx.factorize_filled(vals))
    assert out.tobytes() == out2.tobytes()


def test_robust_diagnostics_match_legacy(problem):
    A, plan, _ = problem
    a = np.asarray(A.data).copy()
    a[0] = 1e-18                            # force a perturbation somewhere
    ref = _reference(plan, jnp.float64, static_pivot=1e-8)
    fx = JaxFactorizer(plan, dtype=jnp.float64, static_pivot=1e-8)
    out_ref = np.asarray(ref.factorize(a))
    out = np.asarray(fx.factorize(a))
    assert out.tobytes() == out_ref.tobytes()
    assert float(fx.last_a_max) == float(ref.last_a_max)
    assert int(fx.last_n_perturbed) == int(ref.last_n_perturbed)


# -- triangular solver ------------------------------------------------------

def test_trisolve_fused_bit_identical(problem):
    A, plan, _ = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    vals = fx.factorize(A.data)
    rng = np.random.default_rng(5)
    b = rng.standard_normal(plan.n)
    legacy = JaxTriangularSolver(plan, fuse_buckets=False, jit_schedule=False)
    fused = JaxTriangularSolver(plan)
    xl = np.asarray(legacy.solve(vals, b))
    xf = np.asarray(fused.solve(vals, b))
    assert xf.tobytes() == xl.tobytes()
    assert fused.last_n_dispatches == 1
    assert legacy.last_n_dispatches > 10
    # batched + multi twins
    vb = jnp.stack([vals, vals * 0.5])
    bb = rng.standard_normal((2, plan.n))
    assert np.asarray(fused.solve_batched(vb, bb)).tobytes() == \
        np.asarray(legacy.solve_batched(vb, bb)).tobytes()
    bm = rng.standard_normal((4, plan.n))
    assert np.asarray(fused.solve_multi(vals, bm)).tobytes() == \
        np.asarray(legacy.solve_multi(vals, bm)).tobytes()


def test_trisolve_fused_does_not_clobber_rhs(problem):
    """The fused runner must not donate the caller's rhs or factor values."""
    A, plan, _ = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    vals = fx.factorize(A.data)
    solver = JaxTriangularSolver(plan)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(plan.n))
    x1 = np.asarray(solver.solve(vals, b))
    x2 = np.asarray(solver.solve(vals, b))      # b and vals still alive
    assert x1.tobytes() == x2.tobytes()


def test_trisolve_sparse_pruned_bit_identical(problem):
    A, plan, _ = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    vals = fx.factorize(A.data)
    pat = [2, 11]
    b = np.zeros(plan.n)
    b[pat] = 1.0
    legacy = JaxTriangularSolver(plan, fuse_buckets=False, jit_schedule=False)
    fused = JaxTriangularSolver(plan)
    _, _, _, breach = fused.schedule_for_pattern(pat)
    xl = np.asarray(legacy.solve(vals, b, rhs_pattern=pat))
    xf = np.asarray(fused.solve(vals, b, rhs_pattern=pat))
    assert xf.tobytes() == xl.tobytes()
    full = np.asarray(fused.solve(vals, b))
    np.testing.assert_array_equal(xf[breach], full[breach])


def test_full_reach_pattern_reuses_full_schedule(problem):
    """Satellite: a pattern whose closure is every column must NOT build a
    pruned twin of the full schedule."""
    _, plan, _ = problem
    solver = JaxTriangularSolver(plan)
    dense_pat = np.arange(plan.n)
    fwd, bwd, freach, breach = solver.schedule_for_pattern(dense_pat)
    assert len(freach) == plan.n and len(breach) == plan.n
    assert fwd is solver._full_schedule[0]
    assert bwd is solver._full_schedule[1]
    # and the executable-cache key resolves to the full schedule's runner
    assert solver._groups_for(dense_pat)[2] == "full"


# -- executable cache -------------------------------------------------------

def test_executable_cache_shared_across_instances(problem):
    """Second executor on the same plan compiles nothing: it gets the SAME
    runner callable back from the process-wide cache."""
    A, plan, _ = problem
    fx1 = JaxFactorizer(plan, dtype=jnp.float64)
    fx1.factorize(A.data)
    r1 = fx1._runner_for("scatter", False)
    cache = default_executable_cache()
    hits0 = cache.stats.hits
    builds0 = cache.stats.builds
    fx2 = JaxFactorizer(plan, dtype=jnp.float64)
    out = np.asarray(fx2.factorize(A.data))
    r2 = fx2._runner_for("scatter", False)
    assert r1 is r2
    assert cache.stats.hits > hits0
    assert cache.stats.builds == builds0        # nothing new was built
    assert out.tobytes() == np.asarray(fx1.factorize(A.data)).tobytes()


def test_private_executable_cache_isolated(problem):
    A, plan, _ = problem
    default_stats0 = default_executable_cache().stats.snapshot()
    private = ExecutableCache(capacity=4)
    fx = JaxFactorizer(plan, dtype=jnp.float64, executable_cache=private)
    fx.factorize(A.data)
    assert len(private) == 1
    assert private.stats.builds == 1
    assert fx._runner_key("scatter", False) in private
    # the process-wide cache was never consulted
    assert default_executable_cache().stats.snapshot() == default_stats0


def test_executable_cache_layout_keys_disjoint(problem):
    """Planar and native runners on the SAME plan+dtype must not collide in
    the executable cache — the layout is part of every runner key."""
    A, plan, _ = problem
    a = np.asarray(A.data, dtype=np.complex128) * (1 + 0.5j)
    cache = ExecutableCache(capacity=16)
    nat = JaxFactorizer(plan, dtype=jnp.complex128, executable_cache=cache)
    pla = JaxFactorizer(plan, dtype=jnp.complex128, layout="planar",
                        executable_cache=cache)
    kn, kp = nat._runner_key("scatter", False), pla._runner_key("scatter", False)
    assert kn != kp
    assert kn[-1] == "native" and kp[-1] == "planar"
    out_n = np.asarray(nat.factorize(a))
    builds_nat = cache.stats.builds
    out_p = np.asarray(unpack_planes(pla.factorize(a)))
    # planar built its own runner — a key collision would have silently
    # handed the native runner planar-shaped inputs
    assert cache.stats.builds > builds_nat
    np.testing.assert_allclose(out_p, out_n, rtol=1e-12, atol=1e-14)
    # trisolve keys carry the layout the same way
    sn = JaxTriangularSolver(plan, executable_cache=cache)
    sp_ = JaxTriangularSolver(plan, layout="planar", executable_cache=cache)
    b = np.random.default_rng(9).standard_normal(plan.n).astype(np.complex128)
    xn = np.asarray(sn.solve(nat.factorize(a), b))
    xp = np.asarray(sp_.solve(pla.factorize(a), b))
    np.testing.assert_allclose(xp, xn, rtol=1e-12, atol=1e-14)


def test_executable_cache_hit_on_repeated_planar(problem):
    """A second planar factorizer on the same plan compiles nothing."""
    A, plan, _ = problem
    a = np.asarray(A.data, dtype=np.complex128) * (1 - 0.25j)
    cache = ExecutableCache(capacity=16)
    fx1 = JaxFactorizer(plan, dtype=jnp.complex128, layout="planar",
                        executable_cache=cache)
    out1 = np.asarray(fx1.factorize(a))
    builds0, hits0 = cache.stats.builds, cache.stats.hits
    fx2 = JaxFactorizer(plan, dtype=jnp.complex128, layout="planar",
                        executable_cache=cache)
    out2 = np.asarray(fx2.factorize(a))
    assert cache.stats.builds == builds0        # nothing new was built
    assert cache.stats.hits > hits0
    assert fx1._runner_for("scatter", False) is fx2._runner_for("scatter", False)
    assert out1.tobytes() == out2.tobytes()


def test_executable_cache_lru_eviction():
    c = ExecutableCache(capacity=2)
    c.get_or_build("a", lambda: "A")
    c.get_or_build("b", lambda: "B")
    c.get_or_build("a", lambda: "A2")           # hit refreshes recency
    c.get_or_build("c", lambda: "C")            # evicts "b"
    assert "b" not in c and "a" in c and "c" in c
    assert c.stats.evictions == 1


# -- facade wiring ----------------------------------------------------------

def test_glu_solve_info_dispatch_counters(problem):
    A, _, _ = problem
    glu = GLU(A, dtype=jnp.float64).factorize()
    b = np.random.default_rng(2).standard_normal(A.n)
    glu.solve(b)
    info = glu.solve_info
    assert info["n_dispatches"] == 1
    assert info["solve_dispatches"] == 1
    assert info["n_groups"] >= 1
    legacy = GLU(A, dtype=jnp.float64, fuse_levels=False,
                 jit_schedule=False).factorize()
    legacy.solve(b)
    li = legacy.solve_info
    assert li["n_dispatches"] >= 10 * info["n_dispatches"]
    assert li["solve_dispatches"] >= 10 * info["solve_dispatches"]


def test_glu_fused_matches_legacy_end_to_end(problem):
    A, _, _ = problem
    b = np.random.default_rng(4).standard_normal(A.n)
    x_fused = GLU(A, dtype=jnp.float64).factorize().solve(b)
    x_legacy = GLU(A, dtype=jnp.float64, fuse_levels=False,
                   jit_schedule=False).factorize().solve(b)
    assert np.asarray(x_fused).tobytes() == np.asarray(x_legacy).tobytes()
