"""Request-scheduler contracts that don't need model weights: dependency
ordering, prompt splicing without caller-visible mutation, and idempotent
re-runs (tier-1 twin of the slow end-to-end tests in test_serving.py)."""
import numpy as np

from repro.serving import Request, ServeEngine


class _StubEngine(ServeEngine):
    """ServeEngine with generation stubbed out: the 'model' echoes a
    deterministic function of the prompt so splicing errors are visible in
    the outputs, and every batch call is recorded."""

    def __init__(self):
        self.calls = []

    def generate_batch(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        self.calls.append(np.array(prompts, copy=True))
        base = prompts.sum(axis=1, keepdims=True).astype(np.int64)
        return (base + np.arange(1, max_new + 1)[None, :]).astype(np.int32)


def _requests():
    return [
        Request(rid=0, tokens=np.arange(1, 9, dtype=np.int32), max_new=4),
        Request(rid=1, tokens=np.arange(20, 28, dtype=np.int32), max_new=4),
        Request(rid=2, tokens=np.arange(50, 54, dtype=np.int32), max_new=4,
                parent=0),
        Request(rid=3, tokens=np.arange(60, 64, dtype=np.int32), max_new=4,
                parent=2),
    ]


def test_scheduler_does_not_mutate_requests():
    eng = _StubEngine()
    reqs = _requests()
    before = [r.tokens.copy() for r in reqs]
    results = eng.run(reqs, batch_size=2)
    assert set(results) == {0, 1, 2, 3}
    for r, orig in zip(reqs, before):
        np.testing.assert_array_equal(r.tokens, orig)


def test_scheduler_rerun_is_idempotent():
    """Re-running the scheduler on the SAME request list must reproduce the
    first run exactly — the old in-place splice double-prepended the parent
    prompt on every re-run."""
    eng = _StubEngine()
    reqs = _requests()
    first = eng.run(reqs, batch_size=2)
    prompts_first = [c.shape for c in eng.calls]
    second = eng.run(reqs, batch_size=2)
    prompts_second = [c.shape for c in eng.calls[len(prompts_first):]]
    assert prompts_first == prompts_second
    for rid in first:
        np.testing.assert_array_equal(first[rid], second[rid])


def test_child_sees_parent_context():
    """The spliced prompt (parent effective prompt + parent output + own
    tokens) is what reaches generate_batch, including for grandchildren."""
    eng = _StubEngine()
    reqs = _requests()
    results = eng.run(reqs, batch_size=2)
    by_len = {c.shape[1]: c for c in eng.calls}
    # child 2: 8 (parent prompt) + 4 (parent output) + 4 (own) = 16
    assert 16 in by_len
    child = by_len[16][0]
    np.testing.assert_array_equal(child[:8], reqs[0].tokens)
    np.testing.assert_array_equal(child[8:12], results[0])
    np.testing.assert_array_equal(child[12:], reqs[2].tokens)
    # grandchild 3: 16 (child effective) + 4 (child output) + 4 (own) = 24
    assert 24 in by_len
    grand = by_len[24][0]
    np.testing.assert_array_equal(grand[:8], reqs[0].tokens)
    np.testing.assert_array_equal(grand[8:12], results[0])
    np.testing.assert_array_equal(grand[12:16], reqs[2].tokens)
    np.testing.assert_array_equal(grand[16:20], results[2])
    np.testing.assert_array_equal(grand[20:], reqs[3].tokens)


def test_independent_requests_batch_together():
    eng = _StubEngine()
    reqs = [Request(rid=i, tokens=np.arange(8, dtype=np.int32), max_new=2)
            for i in range(4)]
    eng.run(reqs, batch_size=4)
    assert len(eng.calls) == 1 and eng.calls[0].shape == (4, 8)
