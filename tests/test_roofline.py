"""Roofline: HLO collective parser + term arithmetic + a real tiny dry-run
cell in a subprocess (proves the dryrun harness end-to-end)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.roofline import Roofline, collective_bytes_from_hlo

SAMPLE_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[32,128]{1,0} all-gather(%p0), dimensions={0}
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%p0, %p0)
  %rs = f32[4,128]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = s8[1024]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  %dot = f32[16,16]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""


def test_collective_parser():
    out = collective_bytes_from_hlo(SAMPLE_HLO)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 32 * 128 * 2
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert out["reduce-scatter"] == 4 * 128 * 4
    assert out["collective-permute"] == 1024
    counts = out["_counts"]
    assert counts["all-reduce"] == 1


def test_roofline_terms_and_dominance():
    r = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                 hlo_flops=197e12, hlo_bytes=819e9, collective_bytes=0.0,
                 collective_detail={}, model_flops=197e12 * 256).finalize()
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert r.dominant in ("compute", "memory")
    assert abs(r.useful_fraction - 1.0) < 1e-9


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """Run one real dry-run cell (whisper, smallest arch) on 512 fake devices."""
    code = (
        "import sys; sys.path.insert(0, 'src');"
        "from pathlib import Path;"
        "from repro.launch.dryrun import run_cell;"
        f"rec = run_cell('whisper-base', 'decode_32k', False, Path(r'{tmp_path}'));"
        "assert rec['ok'], rec.get('error');"
        "print('CELL_OK', rec['roofline']['dominant'])"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=Path(__file__).resolve().parents[1],
                       timeout=560)
    assert "CELL_OK" in r.stdout, r.stdout[-2000:] + "\n" + r.stderr[-3000:]
    files = list(Path(tmp_path).glob("*.json"))
    assert files
    rec = json.loads(files[0].read_text())
    roof = rec["roofline"]
    assert roof["compute_s"] > 0 and roof["memory_s"] > 0
    assert rec["memory"]["temp_bytes_per_device"] < 16e9  # fits v5e HBM
