"""AC small-signal analysis: the complex-valued LU workload.

``ac_sweep`` factorizes A(w) = G + jwC at every frequency point of a sweep
in lockstep on ONE symbolic plan (complex128, batched).  Contracts:

* every frequency point matches a per-frequency scipy complex oracle to a
  componentwise backward error <= 1e-10,
* complex batched factorization == per-matrix single factorization,
* the static-pivot bump rule generalizes to ``tau * d/|d|`` on complex,
* MC64 matching/scaling of a complex matrix equals that of ``|A|``.
"""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

import jax.numpy as jnp

from repro.circuit import Circuit, ac_sweep, rc_grid_circuit
from repro.core import GLU, max_product_matching
from repro.core.planner import PlanCache, set_default_plan_cache
from repro.kernels import ops as kops
from repro.sparse import ac_jacobian
from repro.sparse.csc import CSC


def _berr(A_scipy, x, b) -> float:
    """Componentwise backward error max_i |r_i| / (|A||x| + |b|)_i."""
    r = A_scipy @ x - b
    denom = abs(A_scipy) @ np.abs(x) + np.abs(b)
    return float(np.where(denom > 0, np.abs(r) / np.where(denom > 0, denom, 1),
                          np.where(np.abs(r) > 0, np.inf, 0.0)).max())


def test_ac_rc_lowpass_analytic():
    """Single-node RC: V(w) = 1 / (G + jwC), exactly."""
    ckt = Circuit(2)
    ckt.add_resistor(1, 0, 2.0)            # G = 0.5 S
    ckt.add_capacitor(1, 0, 1e-3)
    ckt.add_ac_current_source(0, 1, 1.0)   # 1A phasor into node 1
    freqs = np.logspace(0, 4, 9)
    res = ac_sweep(ckt, freqs)
    v_exact = 1.0 / (0.5 + 1j * 2 * np.pi * freqs * 1e-3)
    assert res.voltages.dtype == np.complex128
    np.testing.assert_allclose(res.voltages[:, 0], v_exact, rtol=1e-12)


def test_ac_sweep_matches_scipy_oracle():
    """Sweep on an RC/diode grid vs per-frequency scipy splu, and the
    one-plan contract: a single batched complex factorize+solve covers the
    whole sweep, and the symbolic plan is shared with the DC build."""
    cache = PlanCache()
    old = set_default_plan_cache(cache)
    try:
        ckt = rc_grid_circuit(4, 4, with_diodes=True, seed=2)
        ckt.add_ac_current_source(1, 0, 1.0)
        freqs = np.logspace(0, 5, 7)
        res = ac_sweep(ckt, freqs)
        assert res.n_batched_factorizations == 1
        assert res.max_backward_error <= 1e-10
        pat = ckt.pattern()
        vals, rhs = ckt.assemble_ac(res.op_point, freqs)
        assert vals.dtype == np.complex128 and vals.shape == (7, pat.nnz)
        for k in range(len(freqs)):
            A = sp.csc_matrix((vals[k], pat.indices, pat.indptr),
                              shape=(pat.n, pat.n))
            x_ref = spla.splu(A).solve(rhs[k])
            np.testing.assert_allclose(res.voltages[k], x_ref,
                                       rtol=1e-9, atol=1e-12)
            assert _berr(A, res.voltages[k], rhs[k]) <= 1e-10
        # DC op-point build + AC complex build share the pattern: at most
        # two symbolic builds for the whole sweep, and a repeat sweep does
        # zero additional symbolic work
        assert cache.stats.builds <= 2
        builds_before = cache.stats.builds
        res2 = ac_sweep(ckt, freqs)
        assert cache.stats.builds == builds_before
        assert res2.plan_cache_hits == 2
        np.testing.assert_allclose(res2.voltages, res.voltages)
    finally:
        set_default_plan_cache(old)


def test_complex_batched_equals_single():
    A = ac_jacobian(150, omega=2e3, seed=4)
    assert np.iscomplexobj(A.data)
    rng = np.random.default_rng(0)
    B = 4
    batch = np.asarray(A.data)[None, :] * (
        1.0 + 0.05 * rng.uniform(-1, 1, size=(B, A.nnz)))
    b = rng.normal(size=(B, A.n)) + 1j * rng.normal(size=(B, A.n))
    glu = GLU(A, dtype=jnp.complex128)
    xb = glu.factorize_batched(batch).solve_batched(b)
    assert xb.dtype == np.complex128
    for k in range(B):
        Ak = CSC(A.n, A.indptr, A.indices, batch[k])
        xk = GLU(Ak, dtype=jnp.complex128).factorize().solve(b[k])
        np.testing.assert_allclose(xb[k], xk, rtol=1e-12, atol=1e-14)
        assert _berr(Ak.to_scipy(), xb[k], b[k]) <= 1e-12


def test_complex_static_pivot_bump_rule():
    """|d| < tau is bumped to tau * d/|d| — magnitude tau, phase kept;
    exact zeros bump to +tau, real negatives to -tau."""
    d_tiny = 1e-14 * np.exp(1j * 0.7)
    vals = np.array([3.0 + 4.0j, d_tiny, 0.0, -1e-13, 2.0 - 1.0j],
                    dtype=np.complex128)
    diag_idx = jnp.asarray(np.array([0, 1, 2, 3, 5], dtype=np.int32))
    tau = 1e-10
    out, n_bumped = kops.perturb_diags(jnp.asarray(vals), diag_idx,
                                       jnp.asarray(tau))
    out = np.asarray(out)
    assert int(n_bumped) == 3
    np.testing.assert_allclose(out[0], vals[0])          # healthy: untouched
    np.testing.assert_allclose(out[1], tau * np.exp(1j * 0.7), rtol=1e-12)
    np.testing.assert_allclose(out[2], tau)              # zero bumps positive
    np.testing.assert_allclose(out[3], -tau)             # real sign preserved
    np.testing.assert_allclose(out[4], vals[4])


def test_complex_static_pivot_end_to_end():
    """A complex matrix with one crushed diagonal factorizes finitely under
    the guard and reports the bump."""
    A = ac_jacobian(80, omega=1e3, seed=1)
    data = np.asarray(A.data).copy()
    # crush column 0's diagonal: with identity permutations it is consumed
    # at level 0 before any update can restore its magnitude
    k = A.value_index(0, 0)
    data[k] = data[k] / abs(data[k]) * 1e-18
    Ac = CSC(A.n, A.indptr, A.indices, data)
    glu = GLU(Ac, dtype=jnp.complex128, mc64="none", ordering="none",
              static_pivot=1e-12)
    glu.factorize()
    assert np.isfinite(np.asarray(glu.factorized_values())).all()
    assert glu.solve_info["n_perturbed"] >= 1


def test_mc64_matching_on_magnitudes():
    """Duff-Koster on a complex matrix is defined on |a_ij|: the matching
    and the dual scalings must equal those of the magnitude matrix."""
    A = ac_jacobian(120, omega=5e3, seed=6)
    rp_c, Dr_c, Dc_c = max_product_matching(A)
    A_abs = CSC(A.n, A.indptr, A.indices, np.abs(np.asarray(A.data)))
    rp_a, Dr_a, Dc_a = max_product_matching(A_abs)
    np.testing.assert_array_equal(rp_c, rp_a)
    np.testing.assert_allclose(Dr_c, Dr_a)
    np.testing.assert_allclose(Dc_c, Dc_a)
    # scaled magnitudes obey the Duff-Koster bound with matched 1s
    scaled = np.abs(np.asarray(A.data)) * Dr_c[A.indices] * Dc_c[
        np.repeat(np.arange(A.n), np.diff(A.indptr))]
    assert scaled.max() <= 1.0 + 1e-12


def test_ac_sweep_refinement_reports_complex_berr():
    ckt = rc_grid_circuit(3, 3, with_diodes=False, seed=0)
    ckt.add_ac_current_source(1, 0, 0.5 + 0.5j)
    res = ac_sweep(ckt, [10.0, 1e3], refine=2)
    assert res.max_backward_error <= 1e-12
    assert res.voltages.shape == (2, ckt.n)


@pytest.mark.slow
def test_ac_sweep_large_grid():
    ckt = rc_grid_circuit(8, 8, with_diodes=True, seed=3)
    ckt.add_ac_current_source(5, 0, 1.0)
    res = ac_sweep(ckt, np.logspace(0, 6, 25))
    assert res.max_backward_error <= 1e-10
    mag = np.abs(res.voltages[:, 4])
    assert mag[0] > mag[-1]          # low-pass grid


def test_ac_sweep_flags_unconverged_op_point():
    """Regression: ``ac_sweep`` used to linearize silently at whatever point
    the starved DC Newton loop stopped at.  Now the result carries
    ``op_converged`` and a warning fires."""
    ckt = Circuit(2)
    ckt.add_resistor(1, 0, 10.0)
    ckt.add_diode(1, 0)
    ckt.add_current_source(0, 1, 0.1)   # nonzero DC op: Newton must iterate
    ckt.add_ac_current_source(0, 1, 1.0)

    with pytest.warns(RuntimeWarning, match="operating-point Newton"):
        starved = ac_sweep(ckt, [10.0], max_newton=1)
    assert not starved.op_converged
    assert starved.op_newton_iters == 1

    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        healthy = ac_sweep(ckt, [10.0], max_newton=60)
    assert healthy.op_converged
    assert healthy.op_newton_iters > 1
    # the starved linearization point really was wrong
    assert np.abs(starved.op_point - healthy.op_point).max() > 1e-3
