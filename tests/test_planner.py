"""Planner subsystem: SymbolicPlan artifact, content-addressed PlanCache,
``GLU.from_plan``, cross-engine pattern equality, and the preprocessing
acceptance contract (vectorized multiple-x faster than gp, identical output;
re-construction on a known pattern does zero symbolic work)."""
import gc
import time

import numpy as np
import pytest

from repro.circuit import rc_grid_circuit, transient
from repro.core import (
    GLU,
    PlanCache,
    build_symbolic_plan,
    compute_scaling,
    levelize_relaxed,
    plan_factorization,
    plan_key,
    set_default_plan_cache,
    symbolic_fillin_etree,
    symbolic_fillin_gp,
    symbolic_fillin_vectorized,
)
from repro.sparse import CSC, circuit_jacobian, grid_laplacian, rc_ladder

ENGINES = ["gp", "etree", "vectorized"]


@pytest.fixture()
def fresh_default_cache():
    """Isolate the process-wide cache: tests in this module must not see (or
    leave behind) plans from other tests."""
    cache = PlanCache(capacity=8)
    old = set_default_plan_cache(cache)
    yield cache
    set_default_plan_cache(old)


def _revalued(A, factor=3.0):
    """Same pattern, globally rescaled values: the MC64 assignment costs are
    invariant under a global factor, so the matching (and hence the plan
    key) is guaranteed unchanged while every value differs."""
    return CSC(A.n, A.indptr, A.indices, np.asarray(A.data) * factor)


# --------------------------------------------------------------------------
# cache semantics
# --------------------------------------------------------------------------

def test_cache_hit_miss_semantics():
    A = circuit_jacobian(220, avg_degree=4.5, seed=3)
    cache = PlanCache(capacity=4)
    p1, s1, hit1 = plan_factorization(A, cache=cache)
    assert not hit1
    assert cache.stats.misses == 1 and cache.stats.builds == 1
    # same pattern, new values: the symbolic artifact is shared
    p2, s2, hit2 = plan_factorization(_revalued(A), cache=cache)
    assert hit2 and p2 is p1
    assert cache.stats.hits == 1 and cache.stats.builds == 1
    # different pattern: miss
    B = circuit_jacobian(220, avg_degree=4.5, seed=4)
    p3, _, hit3 = plan_factorization(B, cache=cache)
    assert not hit3 and p3 is not p1
    assert cache.stats.misses == 2 and cache.stats.builds == 2


def test_cache_key_contract():
    """Key = (pattern, matching, resolved ordering, resolved symbolic,
    panel_threshold) — and nothing else (values don't enter)."""
    A = circuit_jacobian(150, avg_degree=4.0, seed=5)
    perm = compute_scaling(A, "scale").row_perm
    base = plan_key(A.n, A.indptr, A.indices, perm, "mindeg", "gp", 16)
    assert plan_key(A.n, A.indptr, A.indices, perm, "mindeg", "gp", 16) == base
    # auto resolves to the same concrete methods at this size
    assert plan_key(A.n, A.indptr, A.indices, perm, "auto", "auto", 16) == base
    assert plan_key(A.n, A.indptr, A.indices, perm, "rcm", "gp", 16) != base
    assert plan_key(A.n, A.indptr, A.indices, perm, "mindeg", "etree", 16) != base
    assert plan_key(A.n, A.indptr, A.indices, perm, "mindeg", "gp", 8) != base
    other = np.roll(perm, 1)
    assert plan_key(A.n, A.indptr, A.indices, other, "mindeg", "gp", 16) != base


def test_cache_lru_eviction():
    mats = [circuit_jacobian(90, avg_degree=3.5, seed=s) for s in range(3)]
    cache = PlanCache(capacity=2)
    keys = []
    for A in mats:
        plan, _, _ = plan_factorization(A, cache=cache)
        keys.append(plan.key)
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    assert keys[0] not in cache and keys[1] in cache and keys[2] in cache
    # evicted pattern rebuilds (miss), and pushes out the LRU entry (keys[1])
    _, _, hit = plan_factorization(mats[0], cache=cache)
    assert not hit and cache.stats.builds == 4
    assert keys[1] not in cache
    # touching keys[2] via get keeps it hot
    _, _, hit = plan_factorization(mats[2], cache=cache)
    assert hit


def test_cache_disk_persistence(tmp_path):
    A = circuit_jacobian(130, avg_degree=4.0, seed=9)
    c1 = PlanCache(capacity=4, directory=str(tmp_path))
    plan, _, _ = plan_factorization(A, cache=c1)
    # a fresh cache (new process stand-in) warm-starts from disk
    c2 = PlanCache(capacity=4, directory=str(tmp_path))
    p2, _, hit = plan_factorization(A, cache=c2)
    assert hit and c2.stats.disk_hits == 1 and c2.stats.builds == 0
    assert np.array_equal(p2.pattern.indices, plan.pattern.indices)
    assert np.array_equal(p2.fplan.didx, plan.fplan.didx)
    # memory eviction keeps the disk copy: still a (disk) hit afterwards
    for s in range(4):
        plan_factorization(circuit_jacobian(60, avg_degree=3.0, seed=20 + s),
                           cache=c2)
    assert plan.key not in c2
    _, _, hit = plan_factorization(A, cache=c2)
    assert hit and c2.stats.disk_hits == 2


# --------------------------------------------------------------------------
# GLU.from_plan
# --------------------------------------------------------------------------

def test_from_plan_roundtrip():
    A = circuit_jacobian(180, avg_degree=4.5, seed=11)
    b = np.random.default_rng(1).normal(size=A.n)
    g1 = GLU(A, plan_cache=None)
    A2 = _revalued(A, factor=0.5)
    # reference: full construction on the new values
    x_ref = GLU(A2, plan_cache=None).factorize().solve(b)
    g2 = GLU.from_plan(g1.symbolic_plan, A2)
    assert g2.plan_from_cache
    assert g2.symbolic_plan is g1.symbolic_plan
    x = g2.factorize().solve(b)
    np.testing.assert_allclose(x, x_ref, rtol=1e-12, atol=1e-13)
    assert g2.residual(b, x) < 1e-9


def test_from_plan_rejects_foreign_pattern():
    A = circuit_jacobian(120, avg_degree=4.0, seed=13)
    B = circuit_jacobian(120, avg_degree=4.0, seed=14)
    plan = GLU(A, plan_cache=None).symbolic_plan
    with pytest.raises(ValueError, match="pattern"):
        GLU.from_plan(plan, B)


def test_from_plan_rejects_changed_matching():
    """Values that flip the MC64 matching invalidate the plan."""
    A = circuit_jacobian(60, avg_degree=3.5, seed=15)
    plan = GLU(A, plan_cache=None).symbolic_plan
    data = np.asarray(A.data).copy()
    # crush the diagonal, boost off-diagonals: the max-product matching of
    # the new values must differ from the diagonally-dominant one
    n = A.n
    cols = np.repeat(np.arange(n), np.diff(A.indptr))
    diag = A.indices == cols
    data[diag] *= 1e-9
    data[~diag] *= 1e3
    A_flip = CSC(n, A.indptr, A.indices, data)
    if np.array_equal(compute_scaling(A_flip, "scale").row_perm, plan.row_perm):
        pytest.skip("matching did not flip for this instance")
    with pytest.raises(ValueError, match="matching"):
        GLU.from_plan(plan, A_flip)


# --------------------------------------------------------------------------
# cross-engine pattern equality
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kw", [
    (circuit_jacobian, dict(n=200, avg_degree=4.5, seed=1)),
    (circuit_jacobian, dict(n=240, avg_degree=4.0, pattern_asym=0.4, seed=2)),
    (circuit_jacobian, dict(n=180, avg_degree=5.0, asym=0.5, n_rails=2, seed=3)),
    (grid_laplacian, dict(nx=13, ny=11)),
    (rc_ladder, dict(n=80)),
])
def test_vectorized_equals_gp(gen, kw):
    """The vectorized engine is bit-identical to Gilbert-Peierls: pattern,
    scatter map, and the levelization built on top."""
    A = gen(**kw)
    gp = symbolic_fillin_gp(A)
    vec = symbolic_fillin_vectorized(A)
    assert np.array_equal(gp.indptr, vec.indptr)
    assert np.array_equal(gp.indices, vec.indices)
    assert np.array_equal(gp.a_scatter, vec.a_scatter)
    lg, lv = levelize_relaxed(gp), levelize_relaxed(vec)
    assert np.array_equal(lg.levels, lv.levels)
    assert np.array_equal(lg.order, lv.order)
    assert np.array_equal(lg.level_ptr, lv.level_ptr)
    # etree stays a superset of the exact fill
    et = symbolic_fillin_etree(A)
    gkeys = (np.repeat(np.arange(A.n, dtype=np.int64), np.diff(gp.indptr)) * A.n
             + gp.indices.astype(np.int64))
    ekeys = (np.repeat(np.arange(A.n, dtype=np.int64), np.diff(et.indptr)) * A.n
             + et.indices.astype(np.int64))
    assert np.isin(gkeys, ekeys).all()


def test_cross_engine_through_facade():
    """gp and vectorized agree through the full GLU pipeline (MC64 +
    ordering applied); etree factors to the same solution on its superset."""
    A = circuit_jacobian(260, avg_degree=4.5, n_rails=2, seed=21)
    b = np.random.default_rng(3).normal(size=A.n)
    ref = None
    for engine in ENGINES:
        g = GLU(A, symbolic=engine, plan_cache=None)
        x = g.factorize().solve(b)
        assert g.residual(b, x) < 1e-9, engine
        if ref is None:
            ref = x
        else:
            np.testing.assert_allclose(x, ref, rtol=1e-9, atol=1e-11)
    g_gp = GLU(A, symbolic="gp", plan_cache=None)
    g_vec = GLU(A, symbolic="vectorized", plan_cache=None)
    assert np.array_equal(g_gp.pattern.indices, g_vec.pattern.indices)
    assert np.array_equal(g_gp.levelization.levels, g_vec.levelization.levels)


# --------------------------------------------------------------------------
# acceptance: preprocessing speed + zero symbolic work on rebuild
# --------------------------------------------------------------------------

def test_vectorized_preprocessing_acceptance():
    """On a circuit matrix with >= 20k filled nnz the vectorized engine must
    produce the identical filled pattern + levelization multiple-x faster
    than the per-column python DFS (gate at 2.5x, see below)."""
    A = circuit_jacobian(1200, avg_degree=5.0, seed=0)
    scaling = compute_scaling(A, "scale")

    def build(engine):
        t0 = time.perf_counter()
        plan = build_symbolic_plan(A.n, A.indptr, A.indices, scaling.row_perm,
                                   ordering="mindeg", symbolic=engine)
        return plan, time.perf_counter() - t0

    # GC hygiene for the timed region: late in a full suite run the process
    # holds a multi-GB object graph, and the vectorized engine's
    # allocation-heavy ms-scale stages trigger gen-2 collections that scan
    # all of it (measured 2x inflation of t_vec in-suite vs isolation, with
    # t_gp unaffected — the DFS allocates far less per unit time).  Freeze
    # the existing graph out of collection and disable the collector while
    # timing; best-of-3 below still covers allocator noise.
    gc.collect()
    gc.freeze()
    gc.disable()
    try:
        plan_gp, _ = build("gp")
        t_gp = (plan_gp.build_seconds["symbolic"]
                + plan_gp.build_seconds["levelize"])
        plan_vec, _ = build("vectorized")
        t_vec = (plan_vec.build_seconds["symbolic"]
                 + plan_vec.build_seconds["levelize"])
        for _ in range(2):
            plan_rep, _ = build("vectorized")
            t_vec = min(t_vec, plan_rep.build_seconds["symbolic"]
                        + plan_rep.build_seconds["levelize"])
    finally:
        gc.enable()
        gc.unfreeze()
    assert plan_gp.nnz_filled >= 20_000
    assert np.array_equal(plan_gp.pattern.indptr, plan_vec.pattern.indptr)
    assert np.array_equal(plan_gp.pattern.indices, plan_vec.pattern.indices)
    assert np.array_equal(plan_gp.levelization.levels,
                          plan_vec.levelization.levels)
    speedup = t_gp / max(t_vec, 1e-9)
    # Threshold leaves headroom below the ~6-7x measured in a cold process:
    # a ratio-of-timings gate must not flip on process state (a warm
    # executor-laden suite run measured 2.8-3.0x before the GC hygiene
    # above).  The engineering claim (multiple-x preprocessing speedup,
    # ~7x at PR-4 calibration) is unaffected.
    assert speedup >= 2.5, (
        f"preprocessing speedup {speedup:.1f}x < 2.5x "
        f"(t_gp={t_gp*1e3:.1f}ms t_vec={t_vec*1e3:.1f}ms)")


def test_rebuild_same_pattern_is_pure_cache_hit():
    """A second GLU construction on the same pattern (the transient
    re-scaling rebuild shape: new values, same topology) performs zero
    symbolic fill / dependency work — asserted via planner stats."""
    A = circuit_jacobian(400, avg_degree=4.5, seed=31)
    cache = PlanCache(capacity=4)
    g1 = GLU(A, plan_cache=cache)
    assert not g1.plan_from_cache
    assert cache.stats.snapshot() == dict(hits=0, misses=1, evictions=0,
                                          builds=1, disk_hits=0)
    g2 = GLU(_revalued(A, factor=2.5), plan_cache=cache)
    assert g2.plan_from_cache
    assert g2.symbolic_plan is g1.symbolic_plan
    # zero symbolic work: no new build happened anywhere in the planner
    assert cache.stats.snapshot() == dict(hits=1, misses=1, evictions=0,
                                          builds=1, disk_hits=0)
    # and the two solvers agree numerically
    b = np.random.default_rng(7).normal(size=A.n)
    x2 = g2.factorize().solve(b)
    assert g2.residual(b, x2) < 1e-9


def test_transient_rescaling_rebuild_hits_plan_cache(fresh_default_cache):
    """Tier-1 smoke for the end-to-end path: force the transient driver's
    re-scaling rebuild (refine_tol=0 makes every refined solve report
    non-convergence) and assert the rebuild was served by the plan cache."""
    ckt = rc_grid_circuit(4, 4, with_diodes=True, seed=2)
    res = transient(ckt, t_end=0.01, dt=0.005, refine=1, refine_tol=0.0)
    assert res.n_rescalings >= 1
    # setup build is the one miss; every re-scaling rebuild is a hit
    assert res.plan_cache_hits >= res.n_rescalings
    assert fresh_default_cache.stats.builds == 1
    assert fresh_default_cache.stats.hits >= res.n_rescalings
    assert np.isfinite(res.voltages).all()
    assert res.max_residual < 1e-6
