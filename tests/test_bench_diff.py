"""benchmarks.diff gate semantics: one-sided rows warn-and-skip (never
gate), malformed rows are skipped defensively, and only gated-prefix
regressions beyond the threshold fail."""
import json

from benchmarks.diff import diff, load_rows


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(rows))
    return str(p)


def _row(name, us):
    return {"name": name, "us_per_call": us, "derived": ""}


def test_disjoint_rows_warn_and_skip(tmp_path, capsys):
    old = _write(tmp_path, "old.json", [_row("factorize_old_only", 10.0)])
    new = _write(tmp_path, "new.json", [_row("sweep_sharded_d8", 99.0)])
    assert diff(old, new) == 0
    captured = capsys.readouterr()
    assert captured.err.count("# WARN") == 2       # one removed, one added
    assert "factorize_old_only" in captured.err
    assert "sweep_sharded_d8" in captured.err


def test_gated_regression_fails(tmp_path):
    old = _write(tmp_path, "old.json", [_row("factorize_grid64", 10.0)])
    new = _write(tmp_path, "new.json", [_row("factorize_grid64", 25.0)])
    assert diff(old, new) == 1


def test_ungated_regression_passes(tmp_path):
    # sweep_sharded_ rows are informational — emulated multi-device timing
    # is host-dependent, so a 10x swing must not fail the gate
    old = _write(tmp_path, "old.json", [_row("sweep_sharded_d8", 10.0)])
    new = _write(tmp_path, "new.json", [_row("sweep_sharded_d8", 100.0)])
    assert diff(old, new) == 0


def test_gated_within_threshold_passes(tmp_path):
    old = _write(tmp_path, "old.json", [_row("factorize_grid64", 10.0)])
    new = _write(tmp_path, "new.json", [_row("factorize_grid64", 12.0)])
    assert diff(old, new) == 0


def test_malformed_rows_skipped(tmp_path, capsys):
    rows = [{"name": 1}, {"us_per_call": 3.0}, "not-a-dict",
            _row("factorize_grid64", 10.0)]
    path = _write(tmp_path, "weird.json", rows)
    loaded = load_rows(path)
    assert list(loaded) == ["factorize_grid64"]
    assert capsys.readouterr().err.count("# WARN") == 3
    good = _write(tmp_path, "good.json", [_row("factorize_grid64", 10.0)])
    assert diff(path, good) == 0
