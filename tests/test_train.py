"""Training loop integration: loss decreases, microbatch equivalence,
gradient compression, pipeline determinism, fault handling."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.train import OptConfig, TrainConfig, init_opt_state, make_train_step
from repro.models import init_params


@pytest.fixture(scope="module")
def tiny():
    import dataclasses

    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, vocab_size=256)
    return cfg


@pytest.mark.slow
def test_loss_decreases(tiny, key):
    cfg = tiny
    params = init_params(cfg, key)
    opt_cfg = OptConfig(lr=3e-3, warmup=5, total_steps=40)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig()))
    pipe = TokenPipeline(cfg.padded_vocab, 8, 32, seed=1)
    losses = []
    for s in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::8]


@pytest.mark.slow
def test_microbatch_equivalence(tiny, key):
    """Grad accumulation over 4 microbatches == single big batch."""
    cfg = tiny
    params = init_params(cfg, key)
    opt_cfg = OptConfig(lr=1e-3, warmup=1, total_steps=10, clip_norm=0.0)
    pipe = TokenPipeline(cfg.padded_vocab, 8, 32, seed=2)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    outs = []
    for mb in (1, 4):
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig(microbatches=mb)))
        p2, _, m = step(params, opt, batch)
        outs.append((p2, float(m["loss"])))
    assert abs(outs[0][1] - outs[1][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_compress_grads_runs_and_stays_close(tiny, key):
    cfg = tiny
    params = init_params(cfg, key)
    opt_cfg = OptConfig(lr=1e-3, warmup=1, total_steps=10)
    pipe = TokenPipeline(cfg.padded_vocab, 4, 32, seed=3)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    outs = {}
    for compress in (False, True):
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg,
                                       TrainConfig(compress_grads=compress)))
        p2, _, m = step(params, opt, batch)
        outs[compress] = m
    assert abs(float(outs[True]["loss"]) - float(outs[False]["loss"])) < 1e-5
    # int8 grads distort the norm only mildly
    gn0, gn1 = float(outs[False]["grad_norm"]), float(outs[True]["grad_norm"])
    assert abs(gn0 - gn1) / gn0 < 0.2


def test_adafactor_runs(tiny, key):
    cfg = tiny
    params = init_params(cfg, key)
    opt_cfg = OptConfig(kind="adafactor", lr=1e-3, warmup=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig()))
    pipe = TokenPipeline(cfg.padded_vocab, 4, 32, seed=4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(o2["step"]) == 1


def test_pipeline_determinism_and_skip():
    p1 = TokenPipeline(1000, 4, 16, seed=9)
    p2 = TokenPipeline(1000, 4, 16, seed=9)
    p2.skip_to(5)
    b1 = p1.batch_at(5)
    b2 = next(iter(p2))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding: different hosts see different data
    ph = TokenPipeline(1000, 4, 16, seed=9, host_id=1, num_hosts=2)
    assert not np.array_equal(ph.batch_at(5)["tokens"], b1["tokens"])


def test_preemption_guard_flushes(tmp_path, tiny, key):
    import os
    import signal

    from repro.train.fault import PreemptionGuard

    with PreemptionGuard() as g:
        assert not g.should_stop
        os.kill(os.getpid(), signal.SIGTERM)
        import time

        time.sleep(0.05)
        assert g.should_stop


def test_watchdog_fires():
    import time

    from repro.train.fault import StepWatchdog

    fired = []
    with StepWatchdog(0.05, on_timeout=lambda: fired.append(1)) as w:
        time.sleep(0.15)
    assert w.timed_out and fired
