"""Numeric factorization: oracles, JAX executors (all modes), trisolve."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    GLU,
    JaxFactorizer,
    JaxTriangularSolver,
    build_plan,
    factorize_numpy,
    factorize_numpy_fast,
    leftlooking_numpy,
    split_lu,
    symbolic_fillin_gp,
    trisolve_numpy,
)
from repro.sparse import circuit_jacobian


@pytest.fixture(scope="module")
def problem():
    A = circuit_jacobian(250, avg_degree=4.0, seed=11)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    vals0 = As.filled_csc(A).data
    oracle = factorize_numpy(As, vals0)
    return A, As, plan, vals0, oracle


def test_rightlooking_equals_leftlooking(problem):
    """Paper's claim: Alg. 2 computes the same LU as Alg. 1."""
    _, As, _, vals0, oracle = problem
    ll = leftlooking_numpy(As, vals0)
    np.testing.assert_allclose(oracle, ll, rtol=1e-12, atol=1e-12)


def test_fast_oracle_matches(problem):
    _, As, _, vals0, oracle = problem
    np.testing.assert_allclose(factorize_numpy_fast(As, vals0), oracle, rtol=1e-12)


def test_lu_reconstructs_a(problem):
    A, As, _, _, oracle = problem
    L, U = split_lu(As, oracle)
    err = abs((L @ U) - A.to_scipy()).max()
    assert err < 1e-10


@pytest.mark.parametrize("fuse", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_jax_executor_matches_oracle(problem, fuse, dtype):
    A, _, plan, _, oracle = problem
    fx = JaxFactorizer(plan, dtype=dtype, fuse_levels=fuse)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    tol = 1e-10 if dtype == jnp.float64 else 2e-3
    np.testing.assert_allclose(out, oracle, rtol=tol, atol=tol)


def test_pallas_executor_matches_oracle():
    A = circuit_jacobian(150, avg_degree=3.5, seed=12)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    fx = JaxFactorizer(plan, dtype=jnp.float64, use_pallas=True)
    assert any(g.kind == "pallas" for g in fx._groups)
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


@pytest.mark.slow
def test_double_u_correctness():
    """Level-parallel execution must equal strictly-sequential execution —
    this is exactly the hazard double-U dependencies guard against (paper
    §II-C): if the relaxed levels missed one, the parallel scatter-add
    would read a stale value and diverge from the sequential oracle."""
    for seed in range(5):
        A = circuit_jacobian(120, avg_degree=5.0, seed=seed, asym=0.6)
        As = symbolic_fillin_gp(A)
        plan = build_plan(As)
        oracle = factorize_numpy(As, As.filled_csc(A).data)
        out = np.asarray(JaxFactorizer(plan, dtype=jnp.float64).factorize(
            np.asarray(A.data)))
        np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)


def test_trisolve(problem):
    A, _, plan, _, oracle = problem
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.n)
    x_np = trisolve_numpy(plan, oracle, b)
    ts = JaxTriangularSolver(plan)
    x_j = np.asarray(ts.solve(jnp.asarray(oracle), b))
    np.testing.assert_allclose(x_j, x_np, rtol=1e-10, atol=1e-10)
    # and the solve actually solves the (permuted) system
    assert np.abs(A.to_scipy() @ x_np - b).max() < 1e-8


@pytest.mark.parametrize("ordering", [
    pytest.param("none", marks=pytest.mark.slow),  # no fill reduction: dense-ish
    "mindeg",
    "rcm",
])
def test_glu_facade_solve(ordering):
    A = circuit_jacobian(200, avg_degree=4.0, seed=13)
    rng = np.random.default_rng(1)
    b = rng.normal(size=A.n)
    g = GLU(A, ordering=ordering, dtype=jnp.float64)
    g.factorize()
    x = g.solve(b)
    assert g.residual(b, x) < 1e-9


def test_refactorize_new_values():
    A = circuit_jacobian(150, avg_degree=4.0, seed=14)
    g = GLU(A, dtype=jnp.float64)
    rng = np.random.default_rng(2)
    b = rng.normal(size=A.n)
    for scale in (1.0, 2.5, 0.3):
        g.factorize(np.asarray(A.data) * scale)
        x = g.solve(b)
        r = np.abs(A.to_scipy() @ (x * scale) - b).max()
        assert r < 1e-8


def test_mode_ablation_equivalence():
    """Disabling modes (paper Table III cases) never changes the numbers."""
    A = circuit_jacobian(150, avg_degree=4.0, seed=15)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    base = np.asarray(JaxFactorizer(plan, dtype=jnp.float64).factorize(
        np.asarray(A.data)))
    for disable in (("panel",), ("flat",), ("segmented", "panel")):
        fx = JaxFactorizer(plan, dtype=jnp.float64, disable_modes=disable)
        out = np.asarray(fx.factorize(np.asarray(A.data)))
        np.testing.assert_allclose(out, base, rtol=1e-12, atol=1e-12)


def test_dense_tail_switch():
    """Beyond-paper switch-to-dense: exact result, fewer dispatches."""
    from repro.core import fill_reducing_ordering
    from repro.core.factorize import _find_dense_tail

    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    out = np.asarray(fx.factorize(np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-10, atol=1e-10)
    # the dense tail replaces a suffix of sparse level-steps: fewer scheduled
    # levels run through the scan/flat groups (group COUNTS can tie under
    # bucketed fusion, where many levels collapse into few groups either way)
    def sparse_level_steps(f):
        return sum(g.n_levels for g in f._groups if g.kind in ("scan", "flat"))

    assert sparse_level_steps(fx) < sparse_level_steps(
        JaxFactorizer(plan, dtype=jnp.float64, dense_tail=False))
    # the cut is a clean column partition
    info = fx.dense_tail_info
    levels = plan.levels.levels
    assert levels[: info["c_star"]].max() < info["level_cut"]
    assert levels[info["c_star"]:].min() >= info["level_cut"]
