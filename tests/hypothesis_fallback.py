"""Minimal stand-in for the hypothesis API used by test_property.py.

When the real ``hypothesis`` package is available it should be preferred
(test_property imports this module only on ImportError).  The fallback
draws from a seeded numpy Generator, so the property tests still run —
deterministically — on environments without hypothesis installed.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self.draw_fn = draw_fn


def _coerce(s, rng):
    return s.draw_fn(rng)


class _St:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, allow_nan=True):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            k = int(rng.integers(min_size, max_size + 1))
            return [_coerce(elements, rng) for _ in range(k)]

        return _Strategy(draw)

    @staticmethod
    def composite(fn):
        def build(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: _coerce(s, rng), *args, **kwargs))

        return build


st = _St()


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # property's parameters (it would treat them as fixtures)
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(getattr(wrapper, "_max_examples", 10)):
                drawn = [_coerce(s, rng) for s in strategies]
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
