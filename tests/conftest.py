import os

# Tests run on the host CPU with a single device (the dry-run sets its own
# device count in a separate process).  x64 is enabled because the GLU
# numeric oracles and circuit simulation are validated in float64.
os.environ.setdefault("JAX_ENABLE_X64", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

import jax


_TESTS_SINCE_CLEAR = 0


@pytest.fixture(autouse=True)
def _bounded_xla_code_accumulation():
    """Work around an XLA-CPU crash under long single-process suites: after
    a few hundred distinct jit compilations the NEXT LLVM compile segfaults
    inside ``backend_compile`` (observed at a stable ~190-test mark
    regardless of which test gets there, jaxlib 0.4.36).  Dropping the
    executable caches periodically keeps cumulative emitted code bounded;
    the cost is a handful of recompiles per suite run."""
    global _TESTS_SINCE_CLEAR
    yield
    _TESTS_SINCE_CLEAR += 1
    if _TESTS_SINCE_CLEAR >= 64:
        _TESTS_SINCE_CLEAR = 0
        jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
