import os

# Tests run on the host CPU with a single device (the dry-run sets its own
# device count in a separate process).  x64 is enabled because the GLU
# numeric oracles and circuit simulation are validated in float64.
os.environ.setdefault("JAX_ENABLE_X64", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
