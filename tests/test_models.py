"""Model zoo: cache consistency, scan-vs-loop equivalence, gradients."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import (
    forward_decode,
    forward_prefill,
    forward_train,
    init_params,
    param_specs,
)
from repro.models.model import use_scan


def _extras(cfg, key, B):
    out = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                          jnp.float32)
    if cfg.frontend == "vision_stub":
        out["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return out


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_match_train(arch, key):
    cfg = get_config(arch).reduced()
    if cfg.encoder_layers == 0:
        cfg = dataclasses.replace(cfg, num_layers=max(cfg.num_layers, 8))
    params = init_params(cfg, key)
    B, S = 2, 33
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = _extras(cfg, key, B)
    full, _ = forward_train(params, tokens, cfg, extras)
    lp, cache = forward_prefill(params, tokens[:, :-1], cfg, extras, max_len=S + 4)
    ld, cache = forward_decode(params, tokens[:, -1:], cache, cfg, extras)
    assert float(jnp.abs(lp - full[:, -2]).max()) < 3e-4
    assert float(jnp.abs(ld - full[:, -1]).max()) < 3e-4


@pytest.mark.parametrize("arch", ["qwen2.5-3b",
                                  pytest.param("jamba-v0.1-52b",
                                               marks=pytest.mark.slow),
                                  pytest.param("deepseek-v2-lite-16b",
                                               marks=pytest.mark.slow)])
def test_scan_equals_loop(arch, key):
    """lax.scan over the layer pattern is numerically identical to the
    unrolled python loop."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, num_layers=8, scan_layers=True)
    cfg_noscan = dataclasses.replace(cfg, scan_layers=False)
    assert use_scan(cfg) and not use_scan(cfg_noscan)
    params = init_params(cfg, key)

    # re-arrange stacked params into the per-layer structure
    from repro.models.model import layer_groups

    groups = layer_groups(cfg)
    flat_layers = []
    for gi, g in enumerate(groups):
        gp = params["blocks"][gi]
        if not g["scan"]:
            flat_layers.extend(gp["layers"])
        else:
            for r in range(g["repeat"]):
                for pos in range(g["period"]):
                    flat_layers.append(jax.tree.map(lambda x, r=r: x[r],
                                                    gp["pattern"][pos]))
    params_noscan = dict(params)
    params_noscan["blocks"] = [{"layers": flat_layers}]

    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    a, _ = forward_train(params, tokens, cfg)
    b, _ = forward_train(params_noscan, tokens, cfg_noscan)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["stablelm-1.6b",
                                  pytest.param("mixtral-8x7b",
                                               marks=pytest.mark.slow),
                                  pytest.param("mamba2-2.7b",
                                               marks=pytest.mark.slow)])
def test_gradients_finite(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss(p):
        logits, aux = forward_train(p, tokens, cfg)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (lse - gold).mean() + 0.01 * aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()


def test_param_specs_match_init_shapes(key):
    cfg = get_config("qwen2.5-3b").reduced()
    specs = param_specs(cfg)
    params = init_params(cfg, key)
    from repro.models.model import _SPEC

    spec_leaves = jax.tree.leaves(specs, is_leaf=_SPEC)
    param_leaves = jax.tree.leaves(params)
    assert len(spec_leaves) == len(param_leaves)
    for s, p in zip(spec_leaves, param_leaves):
        assert tuple(s[0]) == p.shape


def test_swa_matches_full_when_window_large(key):
    """Sliding-window attention with window >= S equals full attention."""
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, window=64)
    cfg_full = dataclasses.replace(cfg, attention="full", window=0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    a, _ = forward_train(params, tokens, cfg)
    b, _ = forward_train(params, tokens, cfg_full)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_long_decode_swa_rolling_buffer(key):
    """Decode past the window: the rolling buffer must keep only the last
    ``window`` positions and still match a full-attention reference that is
    masked to the window."""
    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, window=8)
    params = init_params(cfg, key)
    S, extra = 12, 6
    tokens = jax.random.randint(key, (1, S + extra), 0, cfg.vocab_size)
    # reference: run train-mode (banded mask) on growing prefixes
    logits_ref, _ = forward_train(params, tokens, cfg)
    _, cache = forward_prefill(params, tokens[:, :S], cfg, max_len=S + extra)
    outs = []
    for t in range(S, S + extra):
        ld, cache = forward_decode(params, tokens[:, t:t + 1], cache, cfg)
        outs.append(ld)
    for i, t in enumerate(range(S, S + extra - 1)):
        np.testing.assert_allclose(np.asarray(outs[i]),
                                   np.asarray(logits_ref[:, t]),
                                   rtol=3e-4, atol=3e-4)


def test_moe_local_groups_equivalence(key):
    """GShard-style local dispatch groups == global dispatch when dropless
    (the §Perf h1d optimization is numerics-preserving)."""
    import dataclasses

    cfg = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, capacity_factor=2.0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    a, _ = forward_train(params, tokens, cfg)
    b, _ = forward_train(params, tokens,
                         dataclasses.replace(cfg, moe_groups=4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
