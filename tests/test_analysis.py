"""Plan sanitizer: golden plans verify clean, the exact dependency rule is
bracketed by the known-sound rules, and the jaxpr audit enforces the
dispatch/donation contracts."""
import itertools

import numpy as np
import pytest

from repro.analysis import (
    CODES,
    PlanVerificationError,
    VerifyReport,
    audit_factorize,
    audit_trisolve,
    verify_executor,
    verify_glu,
    verify_plan,
    verify_trisolver,
)
from repro.core import (
    GLU,
    dependencies_doubleu,
    dependencies_exact,
    dependencies_relaxed,
    dependencies_upattern,
    symbolic_fillin_gp,
)
from repro.sparse import circuit_jacobian, make_suite_matrix


@pytest.fixture(scope="module")
def A():
    return make_suite_matrix("rajat12_like", scale=0.2, seed=3)


@pytest.fixture(scope="module")
def glu(A):
    g = GLU(A)
    g.factorize()
    return g


# -- golden plans verify clean across the executor matrix ---------------------

@pytest.mark.parametrize(
    "symbolic,fuse,dense",
    list(itertools.product(["gp", "vectorized"], [True, False], [True, False])))
def test_golden_plan_verifies(A, symbolic, fuse, dense):
    g = GLU(A, symbolic=symbolic, fuse_buckets=fuse, dense_tail=dense)
    rep = verify_glu(g, "full")
    assert rep.ok, str(rep)
    # every layer of the verifier actually ran
    for check in ("pattern", "races", "norm", "triples", "scatter",
                  "trisolve_fwd", "trisolve_bwd", "reach", "exec_schedule",
                  "trisolve_schedule", "audit_factorize", "audit_trisolve"):
        assert check in rep.checks


def test_symbolic_plan_verify_method(glu):
    rep = glu.symbolic_plan.verify()
    assert isinstance(rep, VerifyReport)
    assert rep.ok


def test_factorize_plan_verify_method(glu):
    rep = glu.plan.verify()
    assert isinstance(rep, VerifyReport)
    assert rep.ok


def test_verify_plan_accepts_fplan_with_pattern(glu):
    plan = glu.symbolic_plan
    rep = verify_plan(plan.fplan, (plan.perm_indptr, plan.perm_indices))
    assert rep.ok, str(rep)


# -- the exact dependency rule ------------------------------------------------

def _edge_set(src, dst):
    return set(zip(src.tolist(), dst.tolist()))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_exact_edges_bracketed(seed):
    A = circuit_jacobian(60, avg_degree=3.0, seed=seed, asym=0.5)
    As = symbolic_fillin_gp(A)
    exact = _edge_set(*dependencies_exact(As))
    upat = _edge_set(*dependencies_upattern(As))
    doubleu = _edge_set(*dependencies_doubleu(As))
    relaxed = _edge_set(*dependencies_relaxed(As))
    # the executor's true hazard set contains every U-pattern edge and every
    # double-U hazard, and never exceeds the relaxed (sound) superset
    assert upat <= exact
    assert doubleu <= exact
    assert exact <= relaxed


def test_exact_edges_are_forward():
    A = circuit_jacobian(80, avg_degree=3.5, seed=7)
    As = symbolic_fillin_gp(A)
    src, dst = dependencies_exact(As)
    assert np.all(src < dst)


# -- jaxpr audit: dispatch + donation contracts -------------------------------

def test_audit_factorize_filled_donates(glu):
    rep = audit_factorize(glu._factorizer, entry="filled")
    assert rep.ok, str(rep)


def test_audit_factorize_scatter_no_donation(glu):
    rep = audit_factorize(glu._factorizer, entry="scatter")
    assert rep.ok, str(rep)


def test_audit_trisolve_no_donation(glu):
    rep = audit_trisolve(glu._solver)
    assert rep.ok, str(rep)


def test_audit_flags_unfused_dispatch(A):
    g = GLU(A, jit_schedule=False)
    rep = audit_factorize(g._factorizer)
    assert rep.codes == {"AUDIT_DISPATCH"}
    rep = audit_trisolve(g._solver)
    assert rep.codes == {"AUDIT_DISPATCH"}


# -- the GLU(verify=...) knob -------------------------------------------------

def test_glu_verify_full_records_report(A):
    g = GLU(A, verify="full")
    assert g.verify_report is not None and g.verify_report.ok
    g.factorize()
    info = g.solve_info
    assert info["verify_report"]["ok"] is True
    assert info["verify_report"]["n_violations"] == 0


def test_glu_verify_plan_level(A):
    g = GLU(A, verify="plan")
    assert g.verify_report.ok
    # plan level must not trace the runners
    assert "audit_factorize" not in g.verify_report.checks


def test_glu_verify_off_is_default(glu):
    assert glu.verify == "off"
    assert glu.verify_report is None
    assert glu.solve_info["verify_report"] is None


def test_glu_verify_rejects_unknown_value(A):
    with pytest.raises(ValueError, match="verify"):
        GLU(A, verify="maybe")


# -- report mechanics ---------------------------------------------------------

def test_report_raise_and_summary():
    rep = VerifyReport()
    rep.ran("races")
    rep.add("RACE_INTRA_LEVEL", "col 3 and 4 share level 2", src=3, dst=4)
    assert not rep.ok
    assert rep.codes == {"RACE_INTRA_LEVEL"}
    s = rep.summary()
    assert s["ok"] is False and s["codes"] == ["RACE_INTRA_LEVEL"]
    with pytest.raises(PlanVerificationError, match="RACE_INTRA_LEVEL"):
        rep.raise_if_violated()


def test_report_caps_per_code():
    rep = VerifyReport()
    for i in range(VerifyReport.MAX_PER_CODE + 5):
        rep.add("NORM_OOB", f"slot {i}")
    assert len(rep.violations) == VerifyReport.MAX_PER_CODE
    assert rep.violations[0].context["suppressed"] == 5


def test_unknown_code_rejected():
    rep = VerifyReport()
    with pytest.raises(ValueError, match="unknown violation code"):
        rep.add("NOT_A_CODE", "nope")
    assert all(c in CODES for c in rep.codes)


# -- executed-schedule checks accept hand-fed overrides -----------------------

def test_verify_executor_and_trisolver_defaults(glu):
    assert verify_executor(glu._factorizer).ok
    assert verify_trisolver(glu._solver).ok
