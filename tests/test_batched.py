"""Batched refactorization: one plan, many matrices.

Equivalence contract: ``factorize_batched`` / ``solve_batched`` must be
elementwise-equal (within dtype tolerance) to a Python loop of
single-matrix calls, and both must match the sequential host oracles
``factorize_numpy`` / ``trisolve_numpy``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    GLU,
    JaxFactorizer,
    JaxTriangularSolver,
    build_plan,
    factorize_numpy,
    symbolic_fillin_gp,
    trisolve_numpy,
)
from repro.sparse import circuit_jacobian
from repro.sparse.csc import CSC

BATCH_SIZES = [1, 3, 8]


@pytest.fixture(scope="module")
def problem():
    A = circuit_jacobian(140, avg_degree=4.0, seed=7)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    return A, As, plan


def _value_batch(A, batch_size, seed):
    """B value vectors on A's pattern: entrywise +-10% perturbations keep
    the generator's diagonal dominance, so no-pivot LU stays safe."""
    rng = np.random.default_rng(seed)
    return np.asarray(A.data)[None] * (
        1.0 + 0.1 * rng.uniform(-1, 1, size=(batch_size, A.nnz)))


@pytest.fixture(scope="module")
def batches(problem):
    """batch_size -> (value batch, per-matrix host-oracle LU values),
    computed once and shared across the dtype/test parametrizations."""
    A, As, _ = problem
    out = {}
    for bsz in BATCH_SIZES:
        batch = _value_batch(A, bsz, seed=bsz)
        oracles = [
            factorize_numpy(
                As, As.filled_csc(CSC(A.n, A.indptr, A.indices, row)).data)
            for row in batch
        ]
        out[bsz] = (batch, oracles)
    return out


@pytest.mark.parametrize("batch_size", BATCH_SIZES)
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_factorize_batched_matches_loop_and_oracle(problem, batches, dtype,
                                                   batch_size):
    A, _, plan = problem
    fx = JaxFactorizer(plan, dtype=dtype)
    batch, oracles = batches[batch_size]
    out = np.asarray(fx.factorize_batched(batch))
    assert out.shape == (batch_size, plan.nnz)
    tol = 1e-10 if dtype == jnp.float64 else 2e-3
    for i in range(batch_size):
        single = np.asarray(fx.factorize(batch[i]))
        np.testing.assert_array_equal(out[i], single)  # identical dispatch math
        np.testing.assert_allclose(out[i], oracles[i], rtol=tol, atol=tol)


@pytest.mark.parametrize("batch_size", BATCH_SIZES)
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_solve_batched_matches_loop_and_oracle(problem, batches, dtype,
                                               batch_size):
    A, _, plan = problem
    fx = JaxFactorizer(plan, dtype=dtype)
    ts = JaxTriangularSolver(plan)
    batch, oracles = batches[batch_size]
    rng = np.random.default_rng(1)
    bs = rng.normal(size=(batch_size, A.n))
    vals = fx.factorize_batched(batch)
    xs = np.asarray(ts.solve_batched(vals, bs))
    assert xs.shape == (batch_size, A.n)
    tol = 1e-10 if dtype == jnp.float64 else 5e-3
    for i in range(batch_size):
        x1 = np.asarray(ts.solve(vals[i], bs[i]))
        np.testing.assert_array_equal(xs[i], x1)
        x_np = trisolve_numpy(plan, oracles[i], bs[i])
        np.testing.assert_allclose(xs[i], x_np, rtol=tol, atol=tol)


@pytest.mark.slow
def test_factorize_batched_use_pallas(problem):
    """The batched segmented kernel (batch folded into the D grid axis)."""
    A, _, plan = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64, use_pallas=True)
    assert any(g.kind == "pallas" for g in fx._groups)
    batch = _value_batch(A, 4, seed=3)
    out = np.asarray(fx.factorize_batched(batch))
    for i in range(4):
        np.testing.assert_allclose(out[i], np.asarray(fx.factorize(batch[i])),
                                   rtol=1e-12, atol=1e-12)


def test_factorize_batched_dense_tail():
    from repro.core import fill_reducing_ordering

    A0 = circuit_jacobian(500, avg_degree=4.0, seed=22)
    perm = fill_reducing_ordering(A0, "mindeg")
    A = A0.permute(perm, perm)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    fx = JaxFactorizer(plan, dtype=jnp.float64, dense_tail=True)
    if fx.dense_tail_info is None:
        pytest.skip("no dense tail found for this instance")
    batch = _value_batch(A, 3, seed=4)
    out = np.asarray(fx.factorize_batched(batch))
    for i in range(3):
        np.testing.assert_allclose(out[i], np.asarray(fx.factorize(batch[i])),
                                   rtol=1e-11, atol=1e-11)


def test_glu_facade_batched_residuals(problem):
    A, _, _ = problem
    import scipy.sparse as sp

    g = GLU(A, dtype=jnp.float64)
    B = 6
    batch = _value_batch(A, B, seed=5)
    rng = np.random.default_rng(2)
    bs = rng.normal(size=(B, A.n))
    xs = g.factorize_batched(batch).solve_batched(bs)
    for i in range(B):
        Ai = sp.csc_matrix((batch[i], A.indices, A.indptr), shape=(A.n, A.n))
        assert np.abs(Ai @ xs[i] - bs[i]).max() < 1e-8


def test_refactorize_solve_fused(problem):
    A, _, _ = problem
    g = GLU(A, dtype=jnp.float64)
    batch = _value_batch(A, 4, seed=6)
    bs = np.random.default_rng(3).normal(size=(4, A.n))
    fused = g.refactorize_solve(batch, bs)
    staged = g.factorize_batched(batch).solve_batched(bs)
    np.testing.assert_array_equal(fused, staged)
    # single-matrix convenience form
    x1 = g.refactorize_solve(batch[0], bs[0])
    np.testing.assert_array_equal(x1, fused[0])
    # the fused call leaves a usable unbatched factorization behind
    x1b = g.solve(bs[0])
    np.testing.assert_allclose(x1b, fused[0], rtol=1e-12, atol=1e-12)


def test_batched_rejects_wrong_rank(problem):
    A, _, plan = problem
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    with pytest.raises(ValueError):
        fx.factorize_batched(np.asarray(A.data))
    g = GLU(A, dtype=jnp.float64)
    with pytest.raises(ValueError):
        g.factorize_batched(np.asarray(A.data))
