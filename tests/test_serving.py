"""Serving engine: greedy generation + dependency-aware scheduling."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # LM-serving tests; run with -m slow

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    import dataclasses

    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(3))
    return ServeEngine(cfg, params), cfg


def test_generate_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(3, 12)).astype(np.int32)
    a = eng.generate_batch(prompts, 6)
    b = eng.generate_batch(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 6)


def test_generate_matches_unbatched(engine):
    """Batched decode must equal single-request decode (no cross-batch leak)."""
    eng, cfg = engine
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 10)).astype(np.int32)
    both = eng.generate_batch(prompts, 5)
    one = eng.generate_batch(prompts[:1], 5)
    np.testing.assert_array_equal(both[0], one[0])


def test_dependency_scheduling(engine):
    eng, cfg = engine
    rng = np.random.default_rng(2)
    reqs = [
        Request(rid=0, tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new=4),
        Request(rid=1, tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new=4),
        # rid=2 extends rid=0's output (prefix dependency)
        Request(rid=2, tokens=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                max_new=4, parent=0),
    ]
    results = eng.run(reqs, batch_size=2)
    assert set(results) == {0, 1, 2}
    # splicing the parent's output into the child's prompt must NOT mutate
    # the caller's request object
    assert len(reqs[2].tokens) == 4
    # and a second run on the same list is identical (idempotent): the old
    # in-place splice double-prepended the parent prompt on re-run
    again = eng.run(reqs, batch_size=2)
    assert set(again) == {0, 1, 2}
    for rid in (0, 1, 2):
        np.testing.assert_array_equal(results[rid], again[rid])
    assert len(reqs[2].tokens) == 4
