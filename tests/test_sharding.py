"""Sharding rules resolution + an 8-device host-mesh integration test
(run in a subprocess so the main test process keeps 1 device)."""
import subprocess
import sys
from pathlib import Path

from repro.configs import get_config
from repro.distributed.sharding import make_rules


def test_make_rules_respects_attn_tp():
    whisper = get_config("whisper-base")
    rules = make_rules(whisper)
    assert rules["heads"] is None and rules["kv_heads"] is None
    qwen = get_config("qwen2.5-3b")
    rules = make_rules(qwen)
    assert rules["heads"] == "model"


def test_rules_override():
    rules = make_rules(get_config("qwen2.5-3b"), kv_seq="model")
    assert rules["kv_seq"] == "model"


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.sharding import (axis_env, make_rules, tree_shardings,
                                        logical_constraint, sharding_for_spec)
from repro.configs import get_config
from repro.models.model import param_specs

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2.5-3b").reduced()
rules = make_rules(cfg)

# 1. params shard over the mesh without error, divisibility guard works
specs = param_specs(cfg)
shs = tree_shardings(specs, mesh, rules, fsdp=True)
emb_sh = shs["embed"]
assert emb_sh.spec[0] == "model", emb_sh.spec       # vocab 512 % 4 == 0

# 2. logical_constraint inside jit produces the annotated sharding
with axis_env(mesh, rules):
    @jax.jit
    def f(x):
        return logical_constraint(x * 2, "batch", None)
    x = jnp.ones((8, 16))
    y = f(x)
    assert y.sharding.spec[0] == ("data",) or y.sharding.spec[0] == "data", y.sharding

# 3. duplicate-axis guard: experts and expert_ffn both -> model
sh = sharding_for_spec((4, 8, 16), ("experts", None, "expert_ffn"), mesh, rules)
flat = [a for s in sh.spec if s for a in (s if isinstance(s, tuple) else (s,))]
assert len(flat) == len(set(flat)), sh.spec

# 4. a sharded einsum runs end-to-end on 8 devices
with axis_env(mesh, rules):
    @jax.jit
    def g(w, x):
        x = logical_constraint(x, "batch", None)
        return x @ w
    w = jax.device_put(np.ones((16, 32), np.float32),
                       NamedSharding(mesh, P(None, "model")))
    out = g(w, jnp.ones((8, 16)))
    assert out.shape == (8, 32)
print("SUBPROCESS_OK")
"""


def test_eight_device_mesh_integration():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, cwd=Path(__file__).resolve().parents[1],
                       timeout=300)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
