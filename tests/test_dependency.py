"""Dependency detection + levelization (paper §III-A, Algorithms 3 & 4)."""
import numpy as np
import pytest

from repro.core import (
    build_plan,
    dependencies_doubleu,
    dependencies_relaxed,
    dependencies_upattern,
    level_stats,
    levelize,
    levelize_relaxed,
    longest_path_levels,
    symbolic_fillin_gp,
)
from repro.sparse import circuit_jacobian, csc_from_coo


def _levels_reference(n, src, dst):
    """Sequential longest-path oracle (the pre-vectorization levelize loop)."""
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    ptr = np.searchsorted(dst, np.arange(n + 1))
    levels = np.zeros(n, dtype=np.int64)
    for k in range(n):
        s, e = ptr[k], ptr[k + 1]
        if e > s:
            levels[k] = levels[src[s:e]].max() + 1
    return levels


def _edges(pair):
    return set(zip(pair[0].tolist(), pair[1].tolist()))


@pytest.fixture(scope="module")
def filled():
    A = circuit_jacobian(150, avg_degree=4.0, seed=7)
    return symbolic_fillin_gp(A)


def test_relaxed_superset_of_exact(filled):
    """Alg. 4 must find every GLU2.0 dependency (U-pattern + double-U)."""
    exact = _edges(dependencies_upattern(filled)) | _edges(dependencies_doubleu(filled))
    relaxed = _edges(dependencies_relaxed(filled))
    assert exact <= relaxed


def test_doubleu_finds_new_edges():
    """Double-U edges exist that the GLU1.0 U-pattern rule misses — on a
    structurally asymmetric pattern (controlled-source stamps)."""
    A = circuit_jacobian(120, avg_degree=4.0, pattern_asym=0.5, seed=3)
    As = symbolic_fillin_gp(A)
    up = _edges(dependencies_upattern(As))
    du = _edges(dependencies_doubleu(As))
    assert len(du - up) > 0


def test_levelization_is_topological(filled):
    src, dst = dependencies_relaxed(filled)
    lv = levelize_relaxed(filled)
    assert (lv.levels[dst] > lv.levels[src]).all()


def test_levelization_partitions_columns(filled):
    lv = levelize_relaxed(filled)
    seen = np.concatenate([lv.columns_at(l) for l in range(lv.num_levels)])
    assert sorted(seen.tolist()) == list(range(filled.n))


def test_same_levels_glu2_vs_glu3_or_slightly_more(filled):
    """Paper Table II: relaxed levelization adds 'just a few or even zero'
    levels versus exact detection."""
    exact = _edges(dependencies_upattern(filled)) | _edges(dependencies_doubleu(filled))
    src = np.array([e[0] for e in exact], dtype=np.int64)
    dst = np.array([e[1] for e in exact], dtype=np.int64)
    lv2 = levelize(filled.n, src, dst)
    lv3 = levelize_relaxed(filled)
    assert lv3.num_levels >= lv2.num_levels
    assert lv3.num_levels - lv2.num_levels <= max(5, filled.n // 20)


def test_paper_example_double_u():
    """The paper's Fig. 4 case: A(6,4) nonzero => column 6 depends on 4
    (1-based paper indices; 0-based here: column 5 depends on 3)."""
    # build the example matrix of Fig. 1 (8x8, 1-based pattern from the paper)
    coords = [
        (1, 1), (2, 2), (3, 3), (4, 4), (5, 5), (6, 6), (7, 7), (8, 8),
        (2, 1), (6, 1), (1, 2), (5, 2),
        (5, 3), (8, 3), (3, 5), (6, 4), (4, 6),
        (4, 7), (6, 7), (8, 7), (7, 4), (2, 8), (3, 8),
        (8, 5), (7, 6),
    ]
    rows = [r - 1 for r, c in coords]
    cols = [c - 1 for r, c in coords]
    vals = np.where(np.array(rows) == np.array(cols), 4.0, 1.0)
    A = csc_from_coo(8, rows, cols, vals)
    As = symbolic_fillin_gp(A)
    rel = _edges(dependencies_relaxed(As))
    assert (3, 5) in rel  # "look left" finds the double-U dependency 4->6


def test_longest_path_levels_matches_reference(filled):
    """The frontier-swept levelization equals the sequential oracle on real
    dependency graphs (with duplicate edges from the two relaxed rules)."""
    src, dst = dependencies_relaxed(filled)
    np.testing.assert_array_equal(
        longest_path_levels(filled.n, src, dst),
        _levels_reference(filled.n, src, dst))


def test_longest_path_levels_chain_fallback():
    """A pure chain exceeds the frontier round cap and must fall through to
    the sequential sweep — levels stay exact."""
    n = 600
    src = np.arange(n - 1, dtype=np.int64)
    dst = src + 1
    got = longest_path_levels(n, src, dst, round_cap=16)
    np.testing.assert_array_equal(got, np.arange(n))


def test_longest_path_levels_random_dags():
    rng = np.random.default_rng(12)
    for _ in range(10):
        n = int(rng.integers(2, 120))
        m = int(rng.integers(0, 4 * n))
        a = rng.integers(0, n, size=m)
        b = rng.integers(0, n, size=m)
        src = np.minimum(a, b)
        dst = np.maximum(a, b)
        keep = src < dst
        src, dst = src[keep], dst[keep]
        for cap in (1, 4, 128):
            np.testing.assert_array_equal(
                longest_path_levels(n, src, dst, round_cap=cap),
                _levels_reference(n, src, dst))


def test_level_stats_shape(filled):
    lv = levelize_relaxed(filled)
    st = level_stats(filled, lv)
    assert st.shape == (lv.num_levels, 3)
    assert st[:, 0].sum() == filled.n


def test_plan_modes_cover_levels(filled):
    plan = build_plan(filled)
    assert len(plan.segments) == plan.num_levels
    assert {s.mode for s in plan.segments} <= {"flat", "segmented", "panel"}
