"""Value-dtype contracts and regression tests for two confirmed bugs.

1. Silent float64 -> float32 truncation: without ``JAX_ENABLE_X64`` a plain
   ``GLU(A)`` used to emit a UserWarning and silently produce float32
   factors (observed residual 4.5e-7 on a float64 request).  The effective
   dtype is now resolved once at setup and a truncated request raises.
2. rhs donation hazard: the jitted triangular-solve group steps donate the
   rhs buffer; when a caller passed a JAX array already of ``vals.dtype``,
   ``jnp.asarray`` was a no-op and the *caller's* array was deleted
   (``RuntimeError: Array has been deleted`` on the next read).
3. Host oracles used to hard-cast values to float64, destroying complex
   inputs; they now preserve the (promoted) input dtype.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

import jax.numpy as jnp

from repro.core import (
    JaxFactorizer,
    JaxTriangularSolver,
    build_plan,
    factorize_numpy,
    factorize_numpy_fast,
    leftlooking_numpy,
    resolve_value_dtype,
    symbolic_fillin_gp,
    trisolve_numpy,
)
from repro.sparse import ac_jacobian, circuit_jacobian

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Runs in a subprocess WITHOUT JAX_ENABLE_X64 — the plain-library-use
# environment where the silent float32 truncation was observed.
_NO_X64_SCRIPT = """
import numpy as np
import jax.numpy as jnp
from repro.core import GLU
from repro.sparse import circuit_jacobian

A = circuit_jacobian(80, avg_degree=4.0, seed=0)
b = np.random.default_rng(0).normal(size=A.n)

# the float64 default must refuse to silently degrade
try:
    GLU(A)
except ValueError as e:
    assert "truncated" in str(e) and "JAX_ENABLE_X64" in str(e), str(e)
    print("RAISED-OK")
else:
    raise SystemExit("GLU(A) did not raise on a truncated float64 request")

# complex128 is truncated the same way
try:
    GLU(A, dtype=jnp.complex128)
except ValueError:
    print("COMPLEX-RAISED-OK")
else:
    raise SystemExit("GLU did not raise on a truncated complex128 request")

# an explicit float32 request is honored (the host-side Dr/Dc unscaling
# is float64, so the returned x is float64 computed from float32 factors)
glu = GLU(A, dtype=jnp.float32)
assert glu.dtype == np.dtype("float32")
x = glu.factorize().solve(b)
assert np.asarray(glu.factorized_values()).dtype == np.float32
assert glu.residual(b, x) < 1e-4
print("FLOAT32-OK")
"""


def test_truncated_dtype_raises_without_x64():
    env = dict(os.environ)
    env.pop("JAX_ENABLE_X64", None)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _NO_X64_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "RAISED-OK" in out.stdout
    assert "COMPLEX-RAISED-OK" in out.stdout
    assert "FLOAT32-OK" in out.stdout


def test_resolve_value_dtype_with_x64():
    # conftest enables x64, so 64-bit requests resolve to themselves
    assert resolve_value_dtype(jnp.float64) == np.dtype(np.float64)
    assert resolve_value_dtype(jnp.complex128) == np.dtype(np.complex128)
    assert resolve_value_dtype(jnp.float32) == np.dtype(np.float32)


@pytest.fixture(scope="module")
def small_plan():
    A = circuit_jacobian(90, avg_degree=4.0, seed=5)
    As = symbolic_fillin_gp(A)
    return A, As, build_plan(As)


@pytest.mark.parametrize("dtype", [jnp.float64, jnp.complex128])
def test_solve_does_not_delete_caller_rhs(small_plan, dtype):
    """Regression: reusing the rhs after solve()/solve_batched() used to
    raise ``RuntimeError: Array has been deleted`` when the rhs was already
    a JAX array of the factor dtype."""
    A, As, plan = small_plan
    fx = JaxFactorizer(plan, dtype=dtype)
    ts = JaxTriangularSolver(plan)
    vals = fx.factorize(np.asarray(A.data).astype(np.dtype(dtype)))
    b_np = np.arange(1.0, A.n + 1.0).astype(np.dtype(dtype))
    b = jnp.asarray(b_np)
    assert b.dtype == vals.dtype         # the exact no-op-asarray hazard
    x = ts.solve(vals, b)
    np.testing.assert_array_equal(np.asarray(b), b_np)   # b must survive
    r = trisolve_numpy(plan, np.asarray(vals), b_np)
    np.testing.assert_allclose(np.asarray(x), r, rtol=1e-10, atol=1e-12)

    vb = jnp.stack([vals, vals])
    bb = jnp.asarray(np.stack([b_np, 2.0 * b_np]))
    xb = ts.solve_batched(vb, bb)
    np.testing.assert_array_equal(np.asarray(bb),
                                  np.stack([b_np, 2.0 * b_np]))
    np.testing.assert_allclose(np.asarray(xb[1]), 2.0 * np.asarray(xb[0]),
                               rtol=1e-10, atol=1e-12)


def test_refined_solve_keeps_rhs(small_plan):
    A, As, plan = small_plan
    fx = JaxFactorizer(plan, dtype=jnp.float64)
    ts = JaxTriangularSolver(plan)
    vals = fx.factorize(A.data)
    rows = As.indices
    cols = np.repeat(np.arange(A.n), np.diff(As.indptr))
    a_vals = jnp.zeros(As.nnz, dtype=jnp.float64).at[
        jnp.asarray(As.a_scatter)].set(jnp.asarray(A.data))
    b_np = np.linspace(-1, 1, A.n)
    b = jnp.asarray(b_np)
    x, info = ts.solve_refined(vals, b, jnp.asarray(rows), jnp.asarray(cols),
                               a_vals, jnp.abs(a_vals), max_iter=2, tol=1e-14)
    np.testing.assert_array_equal(np.asarray(b), b_np)
    assert info["backward_error"] <= 1e-12


def test_host_oracles_preserve_complex():
    """factorize_numpy / factorize_numpy_fast / leftlooking_numpy on a
    complex circuit matrix, validated against scipy splu."""
    A = ac_jacobian(100, omega=3e3, seed=2)
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    filled = As.filled_csc(A)
    assert filled.data.dtype == np.complex128
    rng = np.random.default_rng(1)
    b = rng.normal(size=A.n) + 1j * rng.normal(size=A.n)
    x_ref = spla.splu(sp.csc_matrix((A.data, A.indices, A.indptr),
                                    shape=(A.n, A.n))).solve(b)
    for fn in (factorize_numpy, factorize_numpy_fast, leftlooking_numpy):
        lu = fn(As, filled.data)
        assert lu.dtype == np.complex128
        x = trisolve_numpy(plan, lu, b)
        assert x.dtype == np.complex128
        np.testing.assert_allclose(x, x_ref, rtol=1e-9, atol=1e-12)


def test_host_oracles_promote_narrow_dtypes():
    A = circuit_jacobian(40, avg_degree=3.0, seed=9)
    As = symbolic_fillin_gp(A)
    vals32 = As.filled_csc(A).data.astype(np.float32)
    assert factorize_numpy(As, vals32).dtype == np.float64
    valsc64 = As.filled_csc(A).data.astype(np.complex64)
    assert factorize_numpy(As, valsc64).dtype == np.complex128


def test_csc_from_coo_preserves_complex():
    from repro.sparse.csc import csc_from_coo

    A = csc_from_coo(2, [0, 1, 0], [0, 1, 1], np.array([1 + 1j, 2.0, -1j]))
    assert A.data.dtype == np.complex128
    B = csc_from_coo(2, [0, 1], [0, 1], [1, 2])     # ints still promote
    assert B.data.dtype == np.float64
