"""Adaptive refactorization ladder: escalation policy unit tests plus the
acceptance scenario — on an ill-conditioned transient (cond >= 1e10
generator) the ladder converges with strictly fewer full rebuilds than the
pre-ladder always-re-scale path, and the per-rung counts land on
``TransientResult``.
"""
import numpy as np
import pytest

from repro.circuit.ladder import RUNGS, LadderConfig, RefactorizationLadder
from repro.circuit.simulate import transient
from repro.sparse import ill_conditioned_jacobian
from repro.sparse.csc import csc_to_dense


# --------------------------------------------------------------------------
# policy unit tests (no solver involved)
# --------------------------------------------------------------------------

class _FakeGLU:
    def __init__(self, refine_converged=None, solve_info=None):
        self.refine_converged = refine_converged
        self.solve_info = solve_info


def test_config_validation():
    with pytest.raises(ValueError):
        LadderConfig(check_growth="sometimes")
    with pytest.raises(ValueError):
        LadderConfig(max_rung=4)


def test_rung_progression_and_kwargs():
    ladder = RefactorizationLadder()
    base = dict(ordering="auto", mc64="none", static_pivot=None,
                plan_cache="default")
    assert ladder.rung_name == "refactorize"
    assert ladder.glu_kwargs(base) == base          # rung 0: no overrides

    assert ladder.escalate(step=0, reason="r1") == "rescale"
    kw = ladder.glu_kwargs(base)
    assert kw["mc64"] == "scale" and kw["static_pivot"] is None

    assert ladder.escalate(step=0, reason="r2") == "bump"
    kw = ladder.glu_kwargs(base)
    assert kw["mc64"] == "scale"
    assert kw["static_pivot"] == ladder.config.pivot_eps
    assert kw["plan_cache"] == "default"            # bump is still a cache hit

    assert ladder.escalate(step=1, reason="r3") == "replan"
    kw = ladder.glu_kwargs(base)
    assert kw["plan_cache"] is None                 # replan bypasses the cache
    assert not ladder.can_escalate()
    with pytest.raises(RuntimeError):
        ladder.escalate()

    assert ladder.counts == {"refactorize": 0, "rescale": 1, "bump": 1,
                             "replan": 1}
    assert ladder.n_full_rebuilds == 3
    assert [e["step"] for e in ladder.events] == [0, 0, 1]


def test_bump_keeps_larger_caller_static_pivot():
    ladder = RefactorizationLadder(LadderConfig(pivot_eps=1e-10))
    ladder.escalate(); ladder.escalate()            # -> bump
    kw = ladder.glu_kwargs(dict(static_pivot=1e-6))
    assert kw["static_pivot"] == 1e-6


def test_retry_at_current_rung_counts():
    ladder = RefactorizationLadder()
    ladder.escalate(step=0, reason="x")
    ladder.retry_at_current_rung(step=3, reason="y")
    assert ladder.counts["rescale"] == 2
    assert ladder.n_full_rebuilds == 2


def test_diagnose_tiers():
    ladder = RefactorizationLadder()
    # tier 1: non-finite solution, no glu consulted at all
    assert ladder.diagnose(_FakeGLU(), np.array([1.0, np.nan])) is not None
    # tier 2: refinement flag (scalar and batched)
    assert ladder.diagnose(_FakeGLU(refine_converged=True)) is None
    assert ladder.diagnose(_FakeGLU(refine_converged=False)) is not None
    assert ladder.diagnose(
        _FakeGLU(refine_converged=np.array([True, False]))) is not None
    # tier 3: growth/min-diag only when refinement didn't run
    healthy = dict(pivot_growth=2.0, min_diag=0.5)
    sick = dict(pivot_growth=1e12, min_diag=0.5)
    assert ladder.diagnose(_FakeGLU(solve_info=healthy)) is None
    assert ladder.diagnose(_FakeGLU(solve_info=sick)) is not None
    assert ladder.diagnose(
        _FakeGLU(solve_info=dict(pivot_growth=2.0, min_diag=0.0))) is not None
    # check_growth="never" skips tier 3; "always" applies it after refinement
    never = RefactorizationLadder(LadderConfig(check_growth="never"))
    assert never.diagnose(_FakeGLU(solve_info=sick)) is None
    always = RefactorizationLadder(LadderConfig(check_growth="always"))
    assert always.diagnose(
        _FakeGLU(refine_converged=True, solve_info=sick)) is not None


# --------------------------------------------------------------------------
# acceptance: ill-conditioned transient, ladder vs always-re-scale
# --------------------------------------------------------------------------

class _LinearStubCircuit:
    """Duck-typed circuit: a FIXED linear system ``A v = b`` every step —
    the minimal harness that drives ``transient``'s Newton/escalation
    machinery on the robustness generator matrices."""

    def __init__(self, A, b):
        self._pat = A
        self._vals = np.asarray(A.data, dtype=np.float64)
        self._b = np.asarray(b, dtype=np.float64)
        self.n = A.n

    def pattern(self):
        return self._pat

    def assemble(self, v, v_prev, dt, t):
        return self._vals.copy(), self._b.copy()


@pytest.fixture(scope="module")
def hard_transient():
    # cond >= 1e10 with crushed pivots: unscaled no-pivot LU stalls
    # iterative refinement, a fresh MC64 scaling repairs it
    A = ill_conditioned_jacobian(200, decades=12.0, tiny_pivots=8, seed=3)
    assert np.linalg.cond(csc_to_dense(A)) >= 1e10
    b = np.random.default_rng(5).standard_normal(A.n)
    return A, b


def test_ladder_beats_always_rescale_on_ill_conditioned_transient(hard_transient):
    """Both runs start from the same degraded configuration (no scaling).
    The pre-ladder policy rebuilds with the SAME configuration once per
    step — it never recovers and pays a rebuild every step.  The ladder
    climbs to the re-scale rung once, stays there (sticky), and converges."""
    A, b = hard_transient
    stub = _LinearStubCircuit(A, b)
    steps = 6
    kwargs = dict(t_end=float(steps), dt=1.0, refine=2, mc64="none",
                  newton_tol=1e-8)

    legacy = transient(stub, escalation="rescale", **kwargs)
    ladder = transient(stub, escalation="ladder", **kwargs)

    # the blunt path rebuilt every step and still never met tolerance
    assert legacy.n_rescalings == steps
    # the ladder escalated once to the re-scale rung and recovered
    assert ladder.ladder_counts["rescale"] == 1
    assert ladder.ladder_counts["bump"] == 0
    assert ladder.ladder_counts["replan"] == 0
    assert ladder.n_full_rebuilds == 1
    # strictly fewer full rebuilds than the always-re-scale path
    assert ladder.n_full_rebuilds < legacy.n_rescalings
    # and it actually converged: the solution solves the original system
    x = ladder.voltages[-1]
    denom = np.abs(A.to_scipy()) @ np.abs(x) + np.abs(b)
    berr = float((np.abs(A.to_scipy() @ x - b) / denom).max())
    assert berr <= 1e-12
    assert np.isfinite(ladder.voltages).all()


def test_ladder_silent_on_healthy_transient():
    from repro.circuit import rc_grid_circuit

    ckt = rc_grid_circuit(4, 4, with_diodes=True, seed=2)
    res = transient(ckt, t_end=0.02, dt=0.005, refine=1)
    assert res.n_full_rebuilds == 0
    assert res.ladder_counts["rescale"] == 0
    assert res.n_factorizations == res.newton_iters.sum()
    assert res.ladder_counts["refactorize"] == res.n_factorizations


def test_escalation_none_never_rebuilds(hard_transient):
    A, b = hard_transient
    stub = _LinearStubCircuit(A, b)
    res = transient(stub, t_end=2.0, dt=1.0, refine=2, mc64="none",
                    escalation="none")
    assert res.n_rescalings == 0 and res.n_full_rebuilds == 0


def test_unknown_escalation_rejected(hard_transient):
    A, b = hard_transient
    with pytest.raises(ValueError):
        transient(_LinearStubCircuit(A, b), t_end=1.0, dt=1.0,
                  escalation="bogus")


def test_ladder_counts_reported_on_sweep():
    from repro.circuit import rc_grid_circuit
    from repro.circuit.simulate import transient_sweep

    ckt = rc_grid_circuit(3, 3, with_diodes=False, seed=1)
    res = transient_sweep(ckt, t_end=0.01, dt=0.005, scales=[0.9, 1.1],
                          refine=1)
    assert set(res.ladder_counts) == set(RUNGS)
    assert res.n_full_rebuilds == 0
