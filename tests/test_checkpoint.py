"""Checkpointing: roundtrip, integrity, retention, resume."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import (
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
            "layers": [{"a": jnp.asarray(rng.normal(size=(4,)))} for _ in range(3)],
        },
        "opt": {"step": jnp.int32(7), "m": jnp.asarray(rng.normal(size=(8, 16)))},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 10, tree)
    out = restore_checkpoint(tmp_path, 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 5, tree)
    man = tmp_path / "step_5" / "manifest.json"
    m = json.loads(man.read_text())
    first = next(iter(m["leaves"]))
    m["leaves"][first]["hash"] = "0" * 32
    man.write_text(json.dumps(m))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(tmp_path, 5, tree)


def test_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, tree, keep=2)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_4", "step_5"]


def test_latest_and_resume(tmp_path):
    tree = _tree()
    ck = Checkpointer(tmp_path, every=2, keep=5)
    assert ck.resume(tree) == (None, 0)
    ck.maybe_save(2, tree)
    ck.maybe_save(3, tree)  # not saved (every=2)
    ck.maybe_save(4, tree)
    assert latest_step(tmp_path) == 4
    restored, step = ck.resume(tree)
    assert step == 4
    assert restored is not None


def test_elastic_restore_resharding(tmp_path):
    """Restore under a different sharding (device count change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    save_checkpoint(tmp_path, 1, tree)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out = restore_checkpoint(tmp_path, 1, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
