"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seeded-rng fallback; same properties, fixed examples
    from hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core import (
    JaxFactorizer,
    build_plan,
    dependencies_doubleu,
    dependencies_relaxed,
    dependencies_upattern,
    factorize_numpy,
    levelize_relaxed,
    symbolic_fillin_etree,
    symbolic_fillin_gp,
    trisolve_numpy,
)
from repro.distributed.collectives import dequantize_int8, quantize_int8
from repro.sparse import circuit_jacobian, csc_from_coo


@st.composite
def random_circuit_matrix(draw):
    n = draw(st.integers(8, 80))
    deg = draw(st.floats(1.5, 6.0))
    seed = draw(st.integers(0, 10_000))
    asym = draw(st.floats(0.0, 0.8))
    return circuit_jacobian(n, avg_degree=deg, seed=seed, asym=asym)


@settings(max_examples=25, deadline=None)
@given(random_circuit_matrix())
def test_relaxed_deps_always_superset(A):
    As = symbolic_fillin_gp(A)
    exact = (set(zip(*map(list, dependencies_upattern(As))))
             | set(zip(*map(list, dependencies_doubleu(As)))))
    relaxed = set(zip(*map(list, dependencies_relaxed(As))))
    assert exact <= relaxed


@settings(max_examples=25, deadline=None)
@given(random_circuit_matrix())
def test_levelization_topological_and_complete(A):
    As = symbolic_fillin_gp(A)
    lv = levelize_relaxed(As)
    src, dst = dependencies_relaxed(As)
    if len(src):
        assert (lv.levels[dst] > lv.levels[src]).all()
    assert np.bincount(lv.levels, minlength=lv.num_levels).sum() == As.n


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(random_circuit_matrix())
def test_parallel_factorization_equals_sequential(A):
    """The central invariant: level-parallel GLU == sequential Alg. 2."""
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    oracle = factorize_numpy(As, As.filled_csc(A).data)
    out = np.asarray(JaxFactorizer(plan, dtype=jnp.float64).factorize(
        np.asarray(A.data)))
    np.testing.assert_allclose(out, oracle, rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(random_circuit_matrix(), st.integers(0, 1000))
def test_solve_residual(A, bseed):
    As = symbolic_fillin_gp(A)
    plan = build_plan(As)
    vals = factorize_numpy(As, As.filled_csc(A).data)
    b = np.random.default_rng(bseed).normal(size=A.n)
    x = trisolve_numpy(plan, vals, b)
    r = np.abs(A.to_scipy() @ x - b).max()
    assert r < 1e-6 * max(1.0, np.abs(b).max())


@settings(max_examples=20, deadline=None)
@given(random_circuit_matrix())
def test_etree_fill_superset(A):
    gp = symbolic_fillin_gp(A)
    et = symbolic_fillin_etree(A)
    gp_set = set(zip(gp.indices.tolist(),
                     np.repeat(np.arange(gp.n), np.diff(gp.indptr)).tolist()))
    et_set = set(zip(et.indices.tolist(),
                     np.repeat(np.arange(et.n), np.diff(et.indptr)).tolist()))
    assert gp_set <= et_set


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=64))
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.asarray(xs, dtype=np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x)).max()
    assert err <= float(scale) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(4, 60), st.integers(0, 100))
def test_csc_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    m = max(1, n // 2)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    vals = rng.normal(size=m)
    A = csc_from_coo(n, rows, cols, vals)
    dense = np.zeros((n, n))
    np.add.at(dense, (rows, cols), vals)
    np.testing.assert_allclose(A.to_scipy().toarray(), dense, atol=1e-12)
