"""Sparse-RHS triangular solves: reach-closure pruning of the level
schedule (Gilbert-Peierls; cf. Ruipeng Li, arXiv 1710.04985) and the
many-RHS batched trisolve.

Contracts:

* the pruned schedule is BIT-identical to the full solve (every kept
  operation is the same floating-point operation; every dropped one would
  have contributed an exact zero),
* the full solve itself matches the sequential ``trisolve_numpy`` oracle,
* reach closures are genuine closures (supersets of the seeds, fixed
  points under another expansion),
* ``solve_multi`` equals K independent single solves bitwise,
* the GLU facade validates patterns and maps them through the row
  permutation correctly.
"""
import numpy as np
import pytest

from repro.core import GLU
from repro.core.plan import reach_closure
from repro.core.triangular import trisolve_numpy
from repro.sparse import circuit_jacobian


@pytest.fixture(scope="module")
def factored():
    A = circuit_jacobian(300, avg_degree=4.5, seed=11)
    glu = GLU(A).factorize()
    return glu


def _one_hot(n, idx, val=1.0):
    b = np.zeros(n)
    b[np.asarray(idx)] = val
    return b


# --------------------------------------------------------------------------
# reach closure machinery
# --------------------------------------------------------------------------

def test_reach_closure_basic():
    # chain 0 -> 1 -> 2 and isolated 3: adjacency col j -> rows below
    adj_ptr = np.array([0, 1, 2, 2, 2], dtype=np.int64)
    adj_rows = np.array([1, 2], dtype=np.int64)
    np.testing.assert_array_equal(reach_closure(4, adj_ptr, adj_rows, [0]),
                                  [0, 1, 2])
    np.testing.assert_array_equal(reach_closure(4, adj_ptr, adj_rows, [3]),
                                  [3])
    np.testing.assert_array_equal(reach_closure(4, adj_ptr, adj_rows, []),
                                  [])
    with pytest.raises(ValueError):
        reach_closure(4, adj_ptr, adj_rows, [4])
    with pytest.raises(ValueError):
        reach_closure(4, adj_ptr, adj_rows, [-1])


def test_plan_reaches_are_closures(factored):
    plan = factored.plan
    seeds = np.array([5, 40, 123])
    fr = plan.fwd_reach(seeds)
    # superset of the seeds, sorted, and a fixed point
    assert set(seeds) <= set(fr)
    assert np.all(np.diff(fr) > 0)
    np.testing.assert_array_equal(plan.fwd_reach(fr), fr)
    br = plan.bwd_reach(fr)
    assert set(fr) <= set(br)
    np.testing.assert_array_equal(plan.bwd_reach(br), br)


# --------------------------------------------------------------------------
# pruned == full, bit for bit; full == numpy oracle
# --------------------------------------------------------------------------

def test_full_solve_matches_numpy_oracle(factored):
    n = factored.n
    vals = np.asarray(factored.factorized_values())
    rng = np.random.default_rng(0)
    b = rng.standard_normal(n)
    ours = np.asarray(factored._solver.solve(factored.factorized_values(), b))
    oracle = trisolve_numpy(factored.plan, vals, b)
    np.testing.assert_allclose(ours, oracle, rtol=1e-12, atol=1e-14)


@pytest.mark.parametrize("pattern", [[0], [17], [3, 200, 250], range(0, 300, 7)])
def test_pruned_solve_bit_identical(factored, pattern):
    n = factored.n
    solver = factored._solver
    vals = factored.factorized_values()
    rng = np.random.default_rng(1)
    b = _one_hot(n, list(pattern), rng.standard_normal(len(list(pattern))))
    full = np.asarray(solver.solve(vals, b))
    pruned = np.asarray(solver.solve(vals, b, rhs_pattern=list(pattern)))
    # exact bitwise agreement on the reach AND off it (both exact zeros;
    # array_equal treats -0.0 == 0.0)
    assert np.array_equal(full, pruned)
    _, _, freach, breach = solver.schedule_for_pattern(list(pattern))
    off = np.setdiff1d(np.arange(n), breach)
    assert np.all(pruned[off] == 0.0)


def test_pruned_full_pattern_is_full_solve(factored):
    n = factored.n
    solver = factored._solver
    vals = factored.factorized_values()
    b = np.random.default_rng(2).standard_normal(n)
    full = np.asarray(solver.solve(vals, b))
    pruned = np.asarray(solver.solve(vals, b, rhs_pattern=np.arange(n)))
    assert np.array_equal(full, pruned)


def test_sparse_schedule_cached(factored):
    solver = factored._solver
    solver._sparse_schedules.clear()
    e1 = solver.schedule_for_pattern([4, 9])
    e2 = solver.schedule_for_pattern(np.array([9, 4, 4]))  # normalized key
    assert e1 is e2
    assert len(solver._sparse_schedules) == 1
    # LRU eviction keeps the cache bounded
    for i in range(solver.SPARSE_SCHEDULE_CAP + 5):
        solver.schedule_for_pattern([i])
    assert len(solver._sparse_schedules) <= solver.SPARSE_SCHEDULE_CAP


# --------------------------------------------------------------------------
# many-RHS solve_multi
# --------------------------------------------------------------------------

def test_solve_multi_matches_single(factored):
    n = factored.n
    solver = factored._solver
    vals = factored.factorized_values()
    B = np.random.default_rng(3).standard_normal((6, n))
    multi = np.asarray(solver.solve_multi(vals, B))
    for k in range(6):
        single = np.asarray(solver.solve(vals, B[k]))
        assert np.array_equal(multi[k], single)


def test_solve_multi_pruned_union_pattern(factored):
    n = factored.n
    solver = factored._solver
    vals = factored.factorized_values()
    pat = [2, 77, 140]
    B = np.zeros((3, n))
    for k, j in enumerate(pat):
        B[k, j] = 1.0
    full = np.asarray(solver.solve_multi(vals, B))
    pruned = np.asarray(solver.solve_multi(vals, B, rhs_pattern=pat))
    assert np.array_equal(full, pruned)


def test_solve_multi_shape_validation(factored):
    with pytest.raises(ValueError):
        factored._solver.solve_multi(factored.factorized_values(),
                                     np.zeros(factored.n))


# --------------------------------------------------------------------------
# GLU facade: permutation mapping + validation
# --------------------------------------------------------------------------

def test_glu_solve_rhs_pattern_matches_full():
    A = circuit_jacobian(250, avg_degree=4.0, seed=5)
    glu = GLU(A).factorize()
    b = _one_hot(A.n, [12], 2.5)
    x_full = glu.solve(b)
    x_pruned = glu.solve(b, rhs_pattern=[12])
    assert np.array_equal(x_full, x_pruned)
    assert glu.residual(b, x_pruned) < 1e-12
    # refined path: pruned initial solve, full-schedule corrections
    x_ref = glu.solve(b, refine=2, rhs_pattern=[12])
    assert glu.residual(b, x_ref) < 1e-12
    assert glu.solve_info["converged"]


def test_glu_solve_multi_end_to_end():
    A = circuit_jacobian(200, avg_degree=4.0, seed=6)
    glu = GLU(A).factorize()
    K = 5
    seeds = [3, 50, 120, 7, 199]
    B = np.zeros((K, A.n))
    for k, j in enumerate(seeds):
        B[k, j] = 1.0
    X = glu.solve_multi(B, rhs_pattern=seeds)
    A_sp = A.to_scipy()
    for k in range(K):
        r = np.abs(A_sp @ X[k] - B[k]).max()
        assert r < 1e-10
        assert np.array_equal(X[k], glu.solve(B[k]))
    # refined many-RHS path
    glu.solve_multi(B, refine=2)
    info = glu.solve_info
    assert np.asarray(info["converged"]).all()
    assert np.asarray(info["backward_error"]).shape == (K,)


def test_glu_rhs_pattern_validation():
    A = circuit_jacobian(60, avg_degree=3.5, seed=7)
    glu = GLU(A).factorize()
    b = _one_hot(A.n, [4, 9])
    with pytest.raises(ValueError):                 # b nonzero outside pattern
        glu.solve(b, rhs_pattern=[4])
    with pytest.raises(ValueError):                 # out of range
        glu.solve(b, rhs_pattern=[4, 9, A.n])
    x = glu.solve(b, rhs_pattern=[4, 9])            # exact support is fine
    assert glu.residual(b, x) < 1e-10


def test_glu_pattern_maps_through_row_permutation():
    """The facade translates original-row patterns to permuted positions:
    a matrix with a non-trivial MC64 row permutation must still give the
    bit-identical pruned solve."""
    A = circuit_jacobian(150, avg_degree=4.0, seed=8)
    glu = GLU(A, mc64="scale").factorize()
    b = _one_hot(A.n, [33])
    assert np.array_equal(glu.solve(b), glu.solve(b, rhs_pattern=[33]))
