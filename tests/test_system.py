"""End-to-end system behaviour: the paper's full flow on a real problem."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GLU
from repro.sparse import make_suite_matrix


@pytest.mark.slow
def test_full_flow_on_suite_matrix():
    """MC64 -> ordering -> symbolic -> levelize -> factorize -> solve,
    on a circuit-style matrix, with refactorization (the SPICE loop)."""
    A = make_suite_matrix("grid64", scale=0.25)  # 16x16 grid = 256 nodes
    rng = np.random.default_rng(0)
    b = rng.normal(size=A.n)
    g = GLU(A, dtype=jnp.float64)
    g.factorize()
    x = g.solve(b)
    assert g.residual(b, x) < 1e-9
    # refactorize with perturbed values on the same pattern
    data2 = np.asarray(A.data) * rng.uniform(0.9, 1.1, size=A.nnz)
    g.factorize(data2)
    x2 = g.solve(b)
    import scipy.sparse as sp

    A2 = sp.csc_matrix((data2, A.indices, A.indptr), shape=(A.n, A.n))
    assert np.abs(A2 @ x2 - b).max() < 1e-7


def test_levels_reduce_sequential_steps():
    """Levelization exposes parallelism: #levels << n (paper's premise)."""
    A = make_suite_matrix("grid64", scale=0.5)
    g = GLU(A, dtype=jnp.float64)
    assert g.num_levels < A.n / 3


@pytest.mark.slow
def test_float32_matches_paper_precision():
    """Paper used fp32 (GPU atomics limitation); fp32 here stays within
    engineering tolerance of fp64 on well-conditioned circuit matrices."""
    A = make_suite_matrix("rajat12_like", scale=0.2)
    b = np.random.default_rng(1).normal(size=A.n)
    x64 = GLU(A, dtype=jnp.float64).factorize().solve(b)
    x32 = GLU(A, dtype=jnp.float32).factorize().solve(b)
    rel = np.abs(x32 - x64).max() / (np.abs(x64).max() + 1e-30)
    assert rel < 1e-3
