"""Mutation fuzzing of the plan sanitizer.

Every corruption class injected by :mod:`repro.analysis.mutate` must be
flagged with (at least) its guaranteed violation codes — zero false
negatives — while the untouched golden plan keeps verifying clean — zero
false positives."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seeded-rng fallback; same properties, fixed examples
    from hypothesis_fallback import given, settings, st

from repro.analysis import (
    MUTATIONS,
    merge_executor_steps,
    mutate_plan,
    verify_executor,
    verify_plan,
)
from repro.core import GLU
from repro.sparse import make_suite_matrix

_CACHE = {}


def _golden():
    """One shared golden GLU (module-lazy: built on first use)."""
    if "glu" not in _CACHE:
        A = make_suite_matrix("rajat12_like", scale=0.2, seed=3)
        _CACHE["glu"] = GLU(A)
    return _CACHE["glu"]


@settings(max_examples=30, deadline=None)
@given(st.integers(0, len(MUTATIONS) - 1), st.integers(0, 10_000))
def test_mutations_flagged_with_expected_codes(kind_i, seed):
    glu = _golden()
    kind = MUTATIONS[kind_i]
    rng = np.random.default_rng(seed)
    mutated, expected, info = mutate_plan(glu.plan, kind, rng)
    rep = verify_plan(mutated, reach_seed_sets=info.get("seed_sets"))
    missing = expected - rep.codes
    assert not missing, (
        f"{kind} (seed {seed}): expected {sorted(expected)}, verifier "
        f"reported {sorted(rep.codes)} — missed {sorted(missing)}")


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_golden_plan_never_flagged(seed):
    glu = _golden()
    rng = np.random.default_rng(seed)
    seeds = [rng.integers(0, glu.n, size=2).tolist()]
    rep = verify_plan(glu.plan,
                      (glu.symbolic_plan.perm_indptr,
                       glu.symbolic_plan.perm_indices),
                      reach_seed_sets=seeds)
    assert rep.ok, str(rep)


@pytest.mark.parametrize("kind", MUTATIONS)
def test_each_mutation_class_deterministic(kind):
    """Every class individually, with a fixed seed (so a regression names
    the class, not just 'some hypothesis example')."""
    glu = _golden()
    rng = np.random.default_rng(1234)
    mutated, expected, info = mutate_plan(glu.plan, kind, rng)
    rep = verify_plan(mutated, reach_seed_sets=info.get("seed_sets"))
    assert expected <= rep.codes, (
        f"{kind}: {sorted(expected)} not in {sorted(rep.codes)}")
    # and the mutation never leaked into the shared golden plan
    assert verify_plan(glu.plan).ok


def test_mutations_do_not_alias_golden_arrays():
    glu = _golden()
    rng = np.random.default_rng(0)
    mutated, _, _ = mutate_plan(glu.plan, "scatter_oob", rng)
    assert mutated.a_scatter is not glu.plan.a_scatter
    assert not np.array_equal(mutated.a_scatter, glu.plan.a_scatter)


def test_merged_executor_steps_race_detected():
    """Fusing two dependent schedule steps (the bucket-merge bug class) is
    caught by the executed-schedule walk even though the plan itself is
    untouched."""
    glu = _golden()
    m = merge_executor_steps(glu._factorizer)
    assert m is not None, "schedule has no mergeable dependent pair"
    kinds, arrays, expected = m
    rep = verify_executor(glu._factorizer, kinds=kinds, group_arrays=arrays)
    assert expected <= rep.codes, str(rep)
    # the factorizer's real schedule still verifies clean
    assert verify_executor(glu._factorizer).ok
