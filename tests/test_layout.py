"""Value-layout abstraction: planar re/im plane storage vs native complex.

Covers the layout module itself (pack/unpack roundtrip, planar arithmetic
against native complex ops, dtype resolution) and the facade-level layout
selection contract: ``auto`` goes planar exactly for complex dtypes under
mode-adaptive (``use_pallas``) execution, the public interface stays native
complex, and any Pallas downgrade is surfaced via
``solve_info["pallas_disabled_reason"]`` instead of silently applied.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import GLU, JaxFactorizer, build_plan, symbolic_fillin_gp
from repro.core.plan import MODE_FLAT, MODE_SEGMENTED, MODE_PANEL
from repro.sparse import (
    ValueLayout,
    circuit_jacobian,
    pabs,
    pack_planes,
    pdiv,
    pmul,
    resolve_layout,
    unpack_planes,
)

# -- layout module --------------------------------------------------------
def test_resolve_layout_auto_and_errors():
    assert resolve_layout("auto", np.complex128) == ValueLayout(
        "planar", np.dtype(np.complex128))
    assert resolve_layout("auto", np.float64) == ValueLayout(
        "native", np.dtype(np.float64))
    assert resolve_layout("native", np.complex64).storage_dtype == \
        np.dtype(np.complex64)
    with pytest.raises(ValueError):
        resolve_layout("planar", np.float64)      # planar needs complex
    with pytest.raises(ValueError):
        resolve_layout("interleaved", np.complex128)


def test_planar_storage_shape_and_dtype():
    lay = resolve_layout("planar", np.complex128)
    assert lay.planar
    assert lay.storage_dtype == np.dtype(np.float64)
    assert lay.storage_shape(7) == (7, 2)
    assert lay.storage_shape(3, 7) == (3, 7, 2)
    nat = resolve_layout("native", np.complex128)
    assert not nat.planar and nat.storage_shape(7) == (7,)
    c64 = resolve_layout("planar", np.complex64)
    assert c64.storage_dtype == np.dtype(np.float32)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    z = rng.standard_normal((5, 3)) + 1j * rng.standard_normal((5, 3))
    p = pack_planes(z)
    assert p.shape == (5, 3, 2) and p.dtype == jnp.float64
    np.testing.assert_array_equal(np.asarray(unpack_planes(p)), z)
    # real input packs with a zero imaginary plane
    r = pack_planes(np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(r[..., 1]), np.zeros(4))


def test_planar_arithmetic_matches_native():
    rng = np.random.default_rng(1)
    a = rng.standard_normal(64) + 1j * rng.standard_normal(64)
    b = rng.standard_normal(64) + 1j * rng.standard_normal(64)
    pa, pb = pack_planes(a), pack_planes(b)
    np.testing.assert_allclose(np.asarray(unpack_planes(pmul(pa, pb))),
                               a * b, rtol=1e-14)
    np.testing.assert_allclose(np.asarray(unpack_planes(pdiv(pa, pb))),
                               a / b, rtol=1e-13)
    np.testing.assert_allclose(np.asarray(pabs(pa)), np.abs(a), rtol=1e-14)


# -- facade layout selection and downgrade surfacing ----------------------
@pytest.fixture(scope="module")
def complex_problem():
    rng = np.random.default_rng(7)
    A = circuit_jacobian(90, avg_degree=4.0, seed=5)
    Ac = dataclasses.replace(
        A, data=A.data.astype(np.complex128)
        * np.exp(1j * rng.uniform(-np.pi, np.pi, A.nnz)))
    return Ac


def test_auto_layout_selection(complex_problem):
    Ac = complex_problem
    # complex + mode-adaptive -> planar, fully on the Pallas path
    g = GLU(Ac, dtype=jnp.complex128, use_pallas=True)
    assert g.layout.name == "planar"
    info = g.factorize().solve_info
    assert info["layout"] == "planar"
    assert info["pallas_disabled_reason"] is None
    assert info["n_dispatches"] == 1
    # complex without use_pallas -> native is the faster flat-XLA lowering
    assert GLU(Ac, dtype=jnp.complex128).layout.name == "native"
    # real dtype never goes planar
    A = dataclasses.replace(Ac, data=np.abs(Ac.data))
    assert GLU(A, dtype=jnp.float64, use_pallas=True).layout.name == "native"


def test_pallas_disabled_reason_surfaced(complex_problem):
    Ac = complex_problem
    cases = [
        (dict(dtype=jnp.complex128, use_pallas=False), "use_pallas"),
        (dict(dtype=jnp.complex128, use_pallas=True, layout="native"),
         "layout='native'"),
        (dict(dtype=jnp.complex128, use_pallas=True, layout="planar",
              mode_override=MODE_FLAT), "mode_override"),
    ]
    for kwargs, needle in cases:
        g = GLU(Ac, **kwargs)
        reason = g._factorizer.pallas_disabled_reason
        assert reason is not None and needle in reason, (kwargs, reason)
        assert g.factorize().solve_info["pallas_disabled_reason"] == reason
    # disable_modes is an executor-level knob
    plan = build_plan(symbolic_fillin_gp(Ac))
    fx = JaxFactorizer(plan, dtype=jnp.complex128, use_pallas=True,
                       layout="planar",
                       disable_modes=(MODE_SEGMENTED, MODE_PANEL))
    assert "disable_modes" in fx.pallas_disabled_reason


def test_planar_facade_interface_stays_native(complex_problem):
    Ac = complex_problem
    rng = np.random.default_rng(3)
    b = rng.standard_normal(Ac.n) + 1j * rng.standard_normal(Ac.n)
    g = GLU(Ac, dtype=jnp.complex128, use_pallas=True, refine=2)
    gn = GLU(Ac, dtype=jnp.complex128, layout="native", refine=2)
    x, xn = g.solve(b), gn.solve(b)
    assert np.asarray(x).dtype == np.complex128
    np.testing.assert_allclose(x, xn, rtol=1e-12, atol=1e-14)
    fv = g.factorized_values()
    assert fv.dtype == jnp.complex128 and fv.shape == (g.nnz_filled,)
    # raw device storage really is planes
    assert g._vals.shape == (g.nnz_filled, 2)
    assert g.solve_info["backward_error"] <= 1e-12
    # batched twin
    batch = np.stack([Ac.data, 1.5 * Ac.data])
    g.factorize_batched(batch)
    xb = g.solve_batched(np.stack([b, 2 * b]))
    # entry 1 solves (1.5 A) x = 2 b  ->  x = (2/1.5) A^{-1} b
    np.testing.assert_allclose(xb[1] * 0.75, xn, rtol=1e-10, atol=1e-12)
    assert g.factorized_values_batched().dtype == jnp.complex128


def test_executor_rejects_planar_for_real_dtype():
    A = circuit_jacobian(40, avg_degree=3.0, seed=2)
    plan = build_plan(symbolic_fillin_gp(A))
    with pytest.raises(ValueError):
        JaxFactorizer(plan, dtype=jnp.float64, layout="planar")
